"""Layer-level numerics: flash attention vs naive softmax, MoE vs per-token
reference, RoPE properties, roofline HLO parser.  The hypothesis-driven
ragged-shape sweep lives in ``test_properties.py`` (guarded import)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, flash_attention
from repro.models.ffn import init_moe_ffn, moe_ffn

RNG = np.random.default_rng(42)


def _naive_attention(q, k, v, causal=True, window=None):
    B, Lq, Hq, Dh = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(np.float32).reshape(B, Lq, Hkv, G, Dh)
    s = np.einsum("bqhgd,bkhd->bhgqk", qf, np.asarray(k, np.float32))
    s /= np.sqrt(Dh)
    qpos = np.arange(Lq)[:, None]
    kpos = np.arange(Lk)[None, :]
    mask = np.ones((Lq, Lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bhgqk,bkhd->bqhgd", p, np.asarray(v, np.float32))
    return out.reshape(B, Lq, Hq, Dh)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 1), (8, 2)])
def test_flash_attention_matches_naive(causal, window, hq, hkv):
    B, L, Dh = 2, 40, 16
    q = RNG.normal(size=(B, L, hq, Dh)).astype(np.float32)
    k = RNG.normal(size=(B, L, hkv, Dh)).astype(np.float32)
    v = RNG.normal(size=(B, L, hkv, Dh)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, window=window, q_chunk=16,
                          kv_chunk=8)
    want = _naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_flash_causal_skip_equivalent():
    """The §Perf flash_skip variant must be numerically identical."""
    B, L, H, Dh = 1, 64, 2, 8
    q = RNG.normal(size=(B, L, H, Dh)).astype(np.float32)
    k = RNG.normal(size=(B, L, H, Dh)).astype(np.float32)
    v = RNG.normal(size=(B, L, H, Dh)).astype(np.float32)
    a = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=True, q_chunk=16, kv_chunk=16)
    b = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=True, q_chunk=16, kv_chunk=16,
                        causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ----------------------------------------------------------------- MoE
def _naive_moe(p, x, cfg):
    """Per-token loop reference (no capacity drops)."""
    T, D = x.shape
    logits = x @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = np.zeros((T, D), np.float32)
    K = cfg.top_k
    for t in range(T):
        top = np.argsort(-probs[t])[:K]
        w = probs[t][top] / probs[t][top].sum()
        for e, wi in zip(top, w):
            g = x[t] @ np.asarray(p["w_gate"][e], np.float32)
            u = x[t] @ np.asarray(p["w_up"][e], np.float32)
            silu = g / (1 + np.exp(-g))
            out[t] += wi * ((silu * u) @ np.asarray(p["w_down"][e], np.float32))
    if cfg.n_shared_experts:
        sp = p["shared"]
        g = x @ np.asarray(sp["w_gate"], np.float32)
        u = x @ np.asarray(sp["w_up"], np.float32)
        out += (g / (1 + np.exp(-g)) * u) @ np.asarray(sp["w_down"], np.float32)
    return out


def test_moe_matches_per_token_reference():
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab=32, ffn="moe", n_experts=4,
        n_shared_experts=1, top_k=2, moe_d_ff=8,
        capacity_factor=8.0,  # no drops
        dtype="float32")
    p = init_moe_ffn(jax.random.PRNGKey(0), cfg)
    x = RNG.normal(size=(1, 12, 16)).astype(np.float32) * 0.5
    got = moe_ffn(p, jnp.asarray(x), cfg)
    want = _naive_moe(p, x[0], cfg)
    np.testing.assert_allclose(np.asarray(got)[0], want, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop — output stays finite and
    shared-expert path still contributes."""
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=8, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab=32, ffn="moe", n_experts=4,
        n_shared_experts=0, top_k=2, moe_d_ff=8, capacity_factor=1.0,
        dtype="float32")
    p = init_moe_ffn(jax.random.PRNGKey(1), cfg)
    x = RNG.normal(size=(2, 16, 8)).astype(np.float32)
    out = moe_ffn(p, jnp.asarray(x), cfg)
    assert np.isfinite(np.asarray(out)).all()


# ----------------------------------------------------------------- RoPE
def test_rope_preserves_inner_products_under_shift():
    """RoPE invariant: <rope(q,i), rope(k,j)> depends only on i-j."""
    Dh = 16
    q = RNG.normal(size=(1, 1, 1, Dh)).astype(np.float32)
    k = RNG.normal(size=(1, 1, 1, Dh)).astype(np.float32)

    def dot_at(pi, pj):
        qr = apply_rope(jnp.asarray(q), jnp.asarray([[pi]]), 1e4)
        kr = apply_rope(jnp.asarray(k), jnp.asarray([[pj]]), 1e4)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3
    assert abs(dot_at(5, 5) - dot_at(0, 0)) < 1e-3


def test_mrope_sections_match_1d_when_positions_equal():
    """With all three position streams equal, M-RoPE == classic RoPE."""
    Dh = 16
    x = RNG.normal(size=(1, 4, 2, Dh)).astype(np.float32)
    pos1 = jnp.arange(4)[None]
    pos3 = jnp.repeat(pos1[..., None], 3, axis=-1)
    a = apply_rope(jnp.asarray(x), pos1, 1e4)
    b = apply_rope(jnp.asarray(x), pos3, 1e4, sections=(2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ----------------------------------------------------------------- roofline
def test_hlo_cost_loop_awareness():
    """flops of a scanned matmul must scale with trip count."""
    from repro.launch.roofline import hlo_cost

    def once(x, w):
        return x @ w

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.ones((32, 32))
    w = jnp.ones((32, 32))
    h1 = jax.jit(once).lower(x, w).compile().as_text()
    h7 = jax.jit(scanned).lower(x, w).compile().as_text()
    c1 = hlo_cost(h1)
    c7 = hlo_cost(h7)
    assert c1["flops"] == pytest.approx(2 * 32**3, rel=0.01)
    assert c7["flops"] == pytest.approx(7 * 2 * 32**3, rel=0.01)


def test_flash_vjp_forward_and_grads_match_naive():
    """Custom-VJP flash (fwd AND grads) == differentiable reference."""
    from repro.models.layers import flash_attention_vjp
    B, L, Hq, Hkv, Dh = 1, 36, 4, 2, 8
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(B, L, Hq, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, L, Hkv, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, L, Hkv, Dh)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(B, L, Hq, Dh)).astype(np.float32))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention_vjp(q, k, v, causal=True, q_chunk=8,
                                           kv_chunk=8) * w)

    def loss_ref(q, k, v):
        G = Hq // Hkv
        qf = q.reshape(B, L, Hkv, G, Dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k) / np.sqrt(Dh)
        mask = jnp.tril(jnp.ones((L, L), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, L, Hq, Dh)
        return jnp.sum(o * w)

    f0, g0 = jax.value_and_grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    f1, g1 = jax.value_and_grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(f0) - float(f1)) < 1e-2
    for a, b in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-3)


def test_flash_vjp_causal_skip_grads():
    from repro.models.layers import flash_attention_vjp
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))

    def loss(skip):
        def f(q, k, v):
            return jnp.sum(flash_attention_vjp(
                q, k, v, causal=True, q_chunk=8, kv_chunk=8,
                causal_skip=skip) ** 2)
        return jax.grad(f)(q, k, v)

    a = loss(False)
    b = loss(True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
