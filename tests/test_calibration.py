"""Calibration subsystem tests (ISSUE 7 tentpole): least-squares fit
recovery, PlanCache/SharedPlanCache persistence with calib counters and
zero re-measures across a simulated restart, snapshot-file round-trips,
and engine auto-calibration gating on ``fallback`` models."""
import numpy as np
import pytest

from repro import compat
from repro.core import calibrate
from repro.core.engine import DynasparseEngine
from repro.core.perfmodel import VCK5000, runtime_fallback
from repro.core.plancache import PlanCache
from repro.core.primitives import SparseCOO
from repro.serving.cache import SharedPlanCache


@pytest.fixture(autouse=True)
def _no_snapshot_env(monkeypatch):
    monkeypatch.delenv(calibrate.SNAPSHOT_ENV, raising=False)


def _fake_model(base=None, **over):
    base = base or runtime_fallback("cpu")
    kw = dict(
        name=f"{base.name}+calib[test,b8,float32]",
        f_dense=base.f_dense, dense_macs_per_cycle=1e3,
        f_sparse=base.f_sparse, spdmm_macs_per_cycle=1e3,
        spmm_macs_per_cycle=1e3, n_sparse_units=1, mem_bw=1e9,
        bytes_per_elem=4, dispatch_overhead=1e-4, skip_block=base.skip_block,
        calibrated=True, backend=compat.backend_kind(), block=8,
        dtype="float32", base=base.name, n_samples=14)
    kw.update(over)
    return calibrate.CalibratedModel(**kw)


def test_fit_linear_recovers_synthetic_coefficients():
    c0, c1 = 2e-3, 3e-9
    samples = [{"t": c0 + c1 * m, "macs": m}
               for m in (1e4, 5e4, 2e5, 1e6)]
    f0, f1, resid = calibrate._fit_linear(samples)
    assert f0 == pytest.approx(c0, rel=1e-6)
    assert f1 == pytest.approx(c1, rel=1e-6)
    assert resid < 1e-6


def test_fit_linear_clamps_nonnegative():
    # decreasing times would fit a negative slope: clamp, don't extrapolate
    samples = [{"t": 1e-3 - 1e-10 * m, "macs": m} for m in (1e4, 1e6)]
    c0, c1, _ = calibrate._fit_linear(samples)
    assert c0 >= 0.0 and c1 > 0.0


def test_get_calibrated_caches_and_counts(monkeypatch):
    calls = []
    fake = _fake_model()
    monkeypatch.setattr(calibrate, "calibrate",
                        lambda *a, **k: calls.append(1) or fake)
    cache = PlanCache()
    base = runtime_fallback("cpu")
    m1 = calibrate.get_calibrated(cache, base, block=8)
    m2 = calibrate.get_calibrated(cache, base, block=8)
    assert m1 is fake and m2 is fake
    assert len(calls) == 1
    assert cache.stats.calib_builds == 1 and cache.stats.calib_hits == 1
    assert cache.calibration_count() == 1


def test_calibration_key_binds_backend_block_dtype():
    base = runtime_fallback("cpu")
    k = calibrate.calibration_key(base, 8, "float32")
    assert k == (compat.backend_kind(), 8, "float32", base.name)
    assert k != calibrate.calibration_key(base, 16, "float32")
    assert k != calibrate.calibration_key(VCK5000, 8, "float32")


def test_snapshot_file_roundtrip_and_replay(tmp_path, monkeypatch):
    base = runtime_fallback("cpu")
    key = calibrate.calibration_key(base, 8, "float32")
    fake = _fake_model(base)
    path = str(tmp_path / "calib" / "snapshot.pkl")
    calibrate.save_snapshot(path, {key: fake})
    loaded = calibrate.load_snapshot(path)
    assert loaded[key] == fake

    # a fresh process (fresh cache) must replay from the snapshot file with
    # ZERO measurements: a real sweep would blow through this sentinel
    def boom(*a, **k):
        raise AssertionError("measured despite snapshot")
    monkeypatch.setattr(calibrate, "calibrate", boom)
    cache = PlanCache()
    n0 = calibrate.measurement_count()
    m = calibrate.get_calibrated(cache, base, block=8, snapshot_path=path)
    assert m == fake
    assert calibrate.measurement_count() == n0
    assert cache.stats.calib_builds == 1   # built from file, not measured


def test_snapshot_env_var_and_write_back(tmp_path, monkeypatch):
    base = runtime_fallback("cpu")
    fake = _fake_model(base)
    monkeypatch.setattr(calibrate, "calibrate", lambda *a, **k: fake)
    path = str(tmp_path / "snapshot.pkl")
    monkeypatch.setenv(calibrate.SNAPSHOT_ENV, path)
    m = calibrate.get_calibrated(PlanCache(), base, block=8)
    assert m is fake
    # the measurement was written back to the env-pointed snapshot
    key = calibrate.calibration_key(base, 8, "float32")
    assert calibrate.load_snapshot(path)[key] == fake


def test_snapshot_rejects_unknown_version(tmp_path):
    import pickle
    path = tmp_path / "bad.pkl"
    path.write_bytes(pickle.dumps({"version": 99, "models": {}}))
    with pytest.raises(ValueError, match="snapshot version"):
        calibrate.load_snapshot(str(path))


def test_shared_cache_restart_replays_zero_measurements(
        tmp_path, monkeypatch):
    """SharedPlanCache.save/load carries the calibration entry: after a
    simulated restart the engine's model resolves with calib_builds == 0
    and no microbenchmark runs."""
    base = runtime_fallback("cpu")
    fake = _fake_model(base)
    monkeypatch.setattr(calibrate, "calibrate", lambda *a, **k: fake)
    cache = SharedPlanCache()
    calibrate.get_calibrated(cache, base, block=8)
    assert cache.calibration_count() == 1
    snap = str(tmp_path / "cache.pkl")
    cache.save(snap)

    def boom(*a, **k):
        raise AssertionError("measured despite warm cache")
    monkeypatch.setattr(calibrate, "calibrate", boom)
    fresh = SharedPlanCache()
    fresh.load(snap)
    assert fresh.calibration_count() == 1
    n0 = calibrate.measurement_count()
    m = calibrate.get_calibrated(fresh, base, block=8)
    assert m == fake
    assert calibrate.measurement_count() == n0
    assert fresh.stats.calib_builds == 0 and fresh.stats.calib_hits == 1


def _toy_coo(rng, n=64, deg=4):
    rows = np.repeat(np.arange(n), deg)
    cols = rng.integers(0, n, size=n * deg)
    coo = np.unique(np.stack([rows, cols], 1), axis=0)
    return SparseCOO(shape=(n, n),
                     rows=np.asarray(coo[:, 0], np.int32),
                     cols=np.asarray(coo[:, 1], np.int32),
                     vals=np.ones(len(coo), np.float32))


def test_engine_auto_calibration_gates_on_fallback(monkeypatch):
    """Analytical models are never calibrated away; fallback models resolve
    through get_calibrated exactly once per engine; the effective model's
    name lands in the plan key, so static and calibrated plans coexist."""
    fake = _fake_model()
    calls = []
    monkeypatch.setattr(calibrate, "calibrate",
                        lambda *a, **k: calls.append(1) or fake)

    eng = DynasparseEngine(interpret=True)          # VCK5000: analytical
    assert eng.runtime_hw() is VCK5000
    assert not calls

    fb = runtime_fallback("cpu")
    eng2 = DynasparseEngine(fb, interpret=True)
    assert eng2.runtime_hw() is fake
    assert eng2.runtime_hw() is fake                # resolved once
    assert len(calls) == 1
    assert eng2.cache.stats.calib_builds == 1

    # calibration="off" trusts the fallback constants as given
    eng3 = DynasparseEngine(fb, interpret=True, calibration="off")
    assert eng3.runtime_hw() is fb

    # an explicit model wins over both
    eng4 = DynasparseEngine(fb, interpret=True, calibration=VCK5000)
    assert eng4.runtime_hw() is VCK5000


def test_engine_plan_key_uses_effective_model(monkeypatch):
    fake = _fake_model()
    monkeypatch.setattr(calibrate, "calibrate", lambda *a, **k: fake)
    rng = np.random.default_rng(0)
    adj = _toy_coo(rng)
    y = rng.normal(size=(64, 16)).astype(np.float32)
    fb = runtime_fallback("cpu")
    cache = PlanCache()
    eng_cal = DynasparseEngine(fb, tile_m=16, tile_n=8, literal=True,
                               interpret=True, cache=cache)
    eng_off = DynasparseEngine(fb, tile_m=16, tile_n=8, literal=True,
                               interpret=True, cache=cache,
                               calibration="off")
    eng_cal.plan(adj, y)
    eng_off.plan(adj, y)
    # two distinct plans in one cache: the calibrated and the static model
    # have different names, so neither shadows the other
    assert cache.plan_count() == 2
