"""In-place fused output assembly (tentpole of ISSUE 3).

The fused kernels' output index maps scatter every task's tile directly
into the final padded ``(M, N)`` canvas of the plan's partition, chained
across primitives via output aliasing — ``_execute_batched`` assembles with
ONE slice, no per-task ``.at[].set`` scatter.  These tests pin the
load-bearing properties: bit-identical results vs the per-task path (all
three primitives, ragged edge tiles), zero-retention for tiles no task
covers, and the per-task fallback for misaligned hand-built geometry.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.core.partition import make_tasks
from repro.core.scheduler import execute_plan
from repro.core import sparsity
from repro.kernels import ops

RNG = np.random.default_rng(17)


def _mixed_ragged_plan():
    """A plan with all three primitives AND ragged edge tiles:
    M=90 over tile_m=32 (extents 32/32/26), N=44 over tile_n=24 (24/20)."""
    rng = np.random.default_rng(1)
    xd = rng.normal(size=(90, 64)).astype(np.float32)
    xd[:32] *= (rng.uniform(size=(32, 64)) < 0.01)
    xd[32:64] *= (rng.uniform(size=(32, 64)) < 0.3)
    yd = rng.normal(size=(64, 44)).astype(np.float32)
    yd[:, :24] *= (rng.uniform(size=(64, 24)) < 0.05)
    r, c = np.nonzero(xd)
    x = SparseCOO(xd.shape, jnp.asarray(r.astype(np.int32)),
                  jnp.asarray(c.astype(np.int32)),
                  jnp.asarray(xd[r, c]), tag="adjacency")
    eng = DynasparseEngine(tile_m=32, tile_n=24, literal=True)
    plan = eng.plan(x, jnp.asarray(yd))
    return plan, xd, yd


def test_inplace_mixed_primitives_ragged_bitwise():
    """Batched in-place assembly == per-task path, bit for bit, on a plan
    mixing GEMM/SpDMM/SpMM with ragged row and column edge tiles."""
    plan, xd, yd = _mixed_ragged_plan()
    prims = {t.primitive for t in plan.stq} | {t.primitive for t in plan.dtq}
    assert prims == {"SpDMM", "SpMM", "GEMM"}, prims

    z_b = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd, batched=True)
    z_p = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd, batched=False)
    np.testing.assert_array_equal(np.asarray(z_b), np.asarray(z_p))
    np.testing.assert_allclose(np.asarray(z_b), xd @ yd,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("primitive", ["GEMM", "SpDMM", "SpMM"])
def test_inplace_single_primitive_ragged_bitwise(primitive):
    """Each fused kernel alone must scatter every tile — including the
    ragged edge tiles — into the right canvas region, matching the per-task
    path bit for bit."""
    rng = np.random.default_rng(7)
    M, K, N = 40, 32, 20            # tiles 16/8 -> extents 16/16/8, 8/8/4
    xd = (rng.normal(size=(M, K)) *
          (rng.uniform(size=(M, K)) < 0.4)).astype(np.float32)
    yd = (rng.normal(size=(K, N)) *
          (rng.uniform(size=(K, N)) < 0.5)).astype(np.float32)
    tm, tn = 16, 8
    row_d = np.asarray(sparsity.stripe_density(jnp.asarray(xd), tm, axis=0))
    col_d = np.asarray(sparsity.stripe_density(jnp.asarray(yd), tn, axis=1))
    part = make_tasks("k", M, K, N, row_d, col_d, tm, tn)
    for t in part.tasks:
        t.primitive = primitive
        t.queue = "DTQ" if primitive == "GEMM" else "STQ"
    stq = [t for t in part.tasks if t.queue == "STQ"]
    dtq = [t for t in part.tasks if t.queue == "DTQ"]

    ops.reset_pallas_call_count()
    z_b = execute_plan(part, stq, dtq, xd, yd, batched=True)
    assert ops.pallas_call_count() == 1          # ONE fused launch
    z_p = execute_plan(part, stq, dtq, xd, yd, batched=False)
    np.testing.assert_array_equal(np.asarray(z_b), np.asarray(z_p))
    np.testing.assert_allclose(np.asarray(z_b), xd @ yd,
                               rtol=1e-4, atol=1e-4)


def test_uncovered_tiles_stay_zero():
    """Tiles belonging to no executed task must come out exactly zero — the
    aliased canvas keeps the zero init where no output index map points."""
    plan, xd, yd = _mixed_ragged_plan()
    part = plan.part
    # drain ONLY the sparse queue: every dense-queue tile region must be 0
    z = execute_plan(part, plan.stq, [], xd, yd, batched=True)
    z = np.asarray(z)
    tm, tn = part.tile_m, part.tile_n
    for task in plan.dtq:
        mi, dj = part.row_extent(task.i), part.col_extent(task.j)
        tile = z[task.i * tm: task.i * tm + mi,
                 task.j * tn: task.j * tn + dj]
        np.testing.assert_array_equal(tile, np.zeros_like(tile))
    # and the sparse-queue tiles are untouched by the omission
    z_full = np.asarray(execute_plan(part, plan.stq, plan.dtq, xd, yd,
                                     batched=True))
    for task in plan.stq:
        mi, dj = part.row_extent(task.i), part.col_extent(task.j)
        np.testing.assert_array_equal(
            z[task.i * tm: task.i * tm + mi,
              task.j * tn: task.j * tn + dj],
            z_full[task.i * tm: task.i * tm + mi,
                   task.j * tn: task.j * tn + dj])


def test_misaligned_tiles_fall_back_and_match():
    """Hand-built geometry whose interior tile boundaries are not
    lcm(block, 8)-aligned cannot use the in-place index maps; batched
    execution must transparently fall back to the per-task path and still
    be correct."""
    rng = np.random.default_rng(3)
    M, K, N = 36, 24, 16
    xd = (rng.normal(size=(M, K)) *
          (rng.uniform(size=(M, K)) < 0.3)).astype(np.float32)
    yd = rng.normal(size=(K, N)).astype(np.float32)
    tm, tn = 12, 8                   # tm = 12 is not a multiple of 8
    row_d = np.asarray(sparsity.stripe_density(jnp.asarray(xd), tm, axis=0))
    col_d = np.asarray(sparsity.stripe_density(jnp.asarray(yd), tn, axis=1))
    part = make_tasks("k", M, K, N, row_d, col_d, tm, tn)
    for t in part.tasks:             # mixed queues across the grid
        t.primitive = "SpDMM" if (t.i + t.j) % 2 else "GEMM"
        t.queue = "STQ" if t.primitive == "SpDMM" else "DTQ"
    stq = [t for t in part.tasks if t.queue == "STQ"]
    dtq = [t for t in part.tasks if t.queue == "DTQ"]

    z_b = execute_plan(part, stq, dtq, xd, yd, batched=True)
    z_p = execute_plan(part, stq, dtq, xd, yd, batched=False)
    np.testing.assert_array_equal(np.asarray(z_b), np.asarray(z_p))
    np.testing.assert_allclose(np.asarray(z_b), xd @ yd,
                               rtol=1e-4, atol=1e-4)


def test_misaligned_tiles_sparse_only_engine_uses_packed_fallback():
    """An engine with misaligned tile sizes and an all-sparse plan executes
    with x=None (graph-scale mode: only packed stripes exist).  The
    per-task fallback must consume those packed stripes instead of
    demanding a dense operand."""
    rng = np.random.default_rng(9)
    n, nnz = 36, 60
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    adj = SparseCOO((n, n),
                    jnp.asarray((flat // n).astype(np.int32)),
                    jnp.asarray((flat % n).astype(np.int32)),
                    jnp.asarray(np.abs(rng.normal(size=nnz)
                                       ).astype(np.float32)),
                    tag="adjacency")
    y = rng.normal(size=(n, 8)).astype(np.float32)
    eng = DynasparseEngine(tile_m=12, tile_n=8, literal=True,
                           mode="sparse_only")
    z, _ = eng.matmul(adj, jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(z), adj.todense() @ y,
                               rtol=1e-4, atol=1e-4)


def test_single_stripe_padded_slots_inplace():
    """nrt == 1 / nct == 1 with tile sizes that aren't lcm-aligned still
    takes the in-place path (slot padding only ever extends past M/N)."""
    rng = np.random.default_rng(5)
    M, K, N = 20, 16, 5              # single 20x5 tile: SM=40? no — SM=ru(20,8)=24, SN=8
    xd = (rng.normal(size=(M, K)) *
          (rng.uniform(size=(M, K)) < 0.4)).astype(np.float32)
    yd = rng.normal(size=(K, N)).astype(np.float32)
    eng = DynasparseEngine(tile_m=128, tile_n=128, literal=True)
    z, _ = eng.matmul(jnp.asarray(xd), jnp.asarray(yd))
    assert z.shape == (M, N)
    np.testing.assert_allclose(np.asarray(z), xd @ yd, rtol=1e-4, atol=1e-4)
