"""Regression guard for the §Perf optimization flags: every variant must
lower + compile and stay numerically consistent with the baseline on
reduced configs (subprocess with 8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, dataclasses, json
    from repro.configs import ARCHS
    from repro.configs.reduced import reduce_config
    from repro.models.registry import build_model
    from repro.launch.mesh import make_mesh_for_devices
    from repro.launch.steps import init_state, make_train_step
    from repro.distributed.sharding import params_shardings, batch_shardings
    from repro.optim.adamw import AdamWConfig

    out = {}
    rng = np.random.default_rng(0)
    mesh = make_mesh_for_devices(8, model_parallel=2)

    def run(arch, **cfg_over):
        cfg = dataclasses.replace(reduce_config(ARCHS[arch]), microbatches=2,
                                  remat="full", **cfg_over)
        bundle = build_model(cfg)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
        step = make_train_step(bundle, AdamWConfig(lr=1e-3, warmup_steps=0))
        with mesh:
            state = init_state(bundle)
            state = dict(state, params=jax.device_put(
                state["params"], params_shardings(state["params"], mesh)))
            b_sh = batch_shardings(batch, mesh)
            _, m = jax.jit(step, in_shardings=(None, b_sh))(state, batch)
        return float(m["loss"])

    # same batch ordering per variant pair
    rng = np.random.default_rng(0)
    base = run("phi3-mini-3.8b")
    rng = np.random.default_rng(0)
    vjp = run("phi3-mini-3.8b", flash_vjp=True, flash_causal_skip=True)
    out["phi3_base"] = base
    out["phi3_vjp_skip"] = vjp

    rng = np.random.default_rng(0)
    moe_b = run("deepseek-v2-lite-16b")
    rng = np.random.default_rng(0)
    moe_s = run("deepseek-v2-lite-16b", moe_dispatch_shard=True)
    out["moe_base"] = moe_b
    out["moe_shard"] = moe_s

    rng = np.random.default_rng(0)
    out["seq_shard"] = run("qwen2.5-3b", seq_shard=True)
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


def test_flash_vjp_skip_loss_matches_baseline(results):
    assert results["phi3_base"] == pytest.approx(results["phi3_vjp_skip"],
                                                 rel=1e-2)


def test_moe_dispatch_shard_loss_matches_baseline(results):
    assert results["moe_base"] == pytest.approx(results["moe_shard"],
                                                rel=1e-2)


def test_seq_shard_compiles_and_is_finite(results):
    import math
    assert math.isfinite(results["seq_shard"])
