"""Per-architecture smoke tests: reduced config, one forward/loss/grad step
and one decode step on CPU — asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.reduced import reduce_config
from repro.models.registry import build_model

ARCH_IDS = list(ARCHS)


def _batch(cfg, B=2, L=32, key=0):
    rng = np.random.default_rng(key)
    if cfg.n_enc_layers:
        return {
            "frames": jnp.asarray(rng.normal(size=(B, L, cfg.d_model))
                                  .astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, 16)),
                                  jnp.int32),
        }
    batch = {}
    if cfg.frontend_prefix > 0:
        lp = int(L * cfg.frontend_prefix)
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, lp, cfg.d_model)).astype(np.float32))
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, L - lp)), jnp.int32)
        if cfg.mrope_sections:
            pos = np.broadcast_to(np.arange(L)[None, :, None],
                                  (B, L, 3)).copy()
            batch["positions"] = jnp.asarray(pos, jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)),
                                      jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = reduce_config(ARCHS[arch])
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = bundle.forward(params, batch)
    assert logits.shape[-1] == cfg.vocab
    assert not np.isnan(np.asarray(logits, np.float32)).any(), arch
    loss = bundle.loss(params, batch)
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grad(arch):
    cfg = reduce_config(ARCHS[arch])
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, key=1)
    loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), arch
    # at least one nonzero gradient
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduce_config(ARCHS[arch])
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(2))
    B, max_len = 2, 16
    cache = bundle.init_cache(B, max_len)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = bundle.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any(), arch
    # second step consumes the updated cache
    logits2, _ = bundle.decode_step(params, cache2, tok, jnp.int32(1))
    assert not np.isnan(np.asarray(logits2, np.float32)).any(), arch


def test_decode_matches_forward_dense():
    """Greedy equivalence: step-by-step decode logits == full forward logits
    (dense arch; validates cache correctness end-to-end)."""
    cfg = reduce_config(ARCHS["phi3-mini-3.8b"])
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab, (1, 8))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    full = np.asarray(bundle.forward(params, batch), np.float32)

    cache = bundle.init_cache(1, 16)
    for t in range(8):
        logits, cache = bundle.decode_step(
            params, cache, jnp.asarray(toks[:, t:t + 1], jnp.int32),
            jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[0], np.float32),
                                   full[0, t], rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "mamba2-780m"])
def test_decode_matches_forward_recurrent(arch):
    """Same greedy equivalence for the sub-quadratic archs — validates the
    recurrent-state decode path against the parallel-scan train path."""
    cfg = reduce_config(ARCHS[arch])
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab, (1, 8))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    full = np.asarray(bundle.forward(params, batch), np.float32)

    cache = bundle.init_cache(1, 16)
    for t in range(8):
        logits, cache = bundle.decode_step(
            params, cache, jnp.asarray(toks[:, t:t + 1], jnp.int32),
            jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[0], np.float32),
                                   full[0, t], rtol=5e-2, atol=5e-2)
