"""Degraded-mode serving (ISSUE 9 tentpole): seeded fault injection, the
compiled→eager→bisect→retry→quarantine ladder, per-request deadlines, the
drift-churn circuit breaker, dispatch-worker health, and corrupt-snapshot
cold starts.

The load-bearing properties, exercised as deterministic seeded sweeps (the
repo's property-test idiom — hypothesis stays an optional dev dependency):

- ISOLATION: a poison request fails ALONE; every fault-free neighbour's
  logits are BIT-EQUAL to a fault-free run (pad_to_max_batch keeps each
  request's column block independent of batch composition).
- LIVENESS: under chaos at every instrumented site, every submitted
  request resolves — logits or a structured error, never a hang.
- DURABILITY: a truncated/garbage/wrong-version snapshot degrades to a
  logged cold start (``snapshot_errors``), and a fault mid-save can never
  clobber the previous snapshot (atomic replace).
"""
import asyncio
import os
import pickle

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.core import calibrate
from repro.core.plancache import PlanCache
from repro.core.perfmodel import runtime_fallback
from repro.distributed.fault import FaultMonitor
from repro.models import gnn
from repro.serving import (DeadlineExceeded, FaultInjector, InjectedFault,
                           ServingConfig, ServingEngine, SharedPlanCache,
                           SketchConfig)
from repro.serving.faults import KNOWN_SITES

RNG = np.random.default_rng(11)


def _rand_graph(n=80, nnz=240, seed=5):
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=nnz)
                                        ).astype(np.float32)),
                     tag="adjacency")


ADJ = _rand_graph()
# hidden/out widths are MULTIPLES of tile_n (8) so no kernel column tile
# ever straddles a request boundary: per-tile sparse/dense routing then
# depends only on a request's own columns, which is what makes per-request
# results BIT-independent of batch composition (the isolation gate below).
PARAMS = gnn.init_params("GCN", 12, 8, 8)


def _feats(i, n=80, d=12):
    rng = np.random.default_rng(1000 + i)
    return rng.normal(size=(n, d)).astype(np.float32)


def _serving(*, faults=None, max_batch=4, max_retries=1, drift=None,
             timeout=None, backoff=0.0, breaker=(3, 60.0, 30.0)):
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                           cache=SharedPlanCache())
    # activation_skip off: the block-skip route's capacity/overflow decision
    # is GLOBAL to the kernel, so a neighbour's activations could flip the
    # whole kernel between the BlockCSR and dense routes — composition-
    # dependent bits, incompatible with the bit-equality isolation gate
    cfg = ServingConfig(
        max_batch=max_batch, sketch=SketchConfig(threshold=drift),
        activation_skip=False,
        max_retries=max_retries, retry_backoff_s=backoff,
        request_timeout=timeout, breaker_threshold=breaker[0],
        breaker_window_s=breaker[1], breaker_cooldown_s=breaker[2],
        faults=faults)
    srv = ServingEngine("GCN", PARAMS, engine=eng, config=cfg)
    srv.register_graph("g", ADJ)
    return srv


def _warm(srv, max_batch=4):
    """Serve one FIXED warmup burst so both the reference run and a chaos
    run plan/compile the identical program from the identical operand.
    The engine's plan is global and density-dependent, so bit-equality
    across runs needs the program pinned before chaos begins; it also
    offsets request ids by ``max_batch`` (poison matches account for it).
    """
    srv.serve(("g", _feats(900 + j)) for j in range(max_batch))


def _reference(n_requests=8, max_batch=4, warm=True):
    srv = _serving(max_batch=max_batch)
    try:
        if warm:
            _warm(srv, max_batch)
        return [np.asarray(z) for z in
                srv.serve(("g", _feats(i)) for i in range(n_requests))]
    finally:
        srv.close()


_REF8_CACHE: list = []


def ref8():
    """Fault-free pre-warmed reference logits, computed once per session
    (lazily — an import-time engine run would tax unrelated collection)."""
    if not _REF8_CACHE:
        _REF8_CACHE.append(_reference(8))
    return _REF8_CACHE[0]


# ------------------------------------------------------------- injector
def test_injector_rejects_unknown_site_and_bad_rate():
    fi = FaultInjector(seed=0)
    with pytest.raises(ValueError, match="unknown fault site"):
        fi.arm("warp_core")
    with pytest.raises(ValueError, match="rate"):
        fi.arm("plan", rate=1.5)


def test_injector_fires_deterministically_per_seed():
    """Same seed → identical firing pattern; sites own independent
    streams, so probing one site never shifts another's pattern."""
    def pattern(seed, extra_probes=0):
        fi = FaultInjector(seed=seed).arm("plan", rate=0.4)
        for _ in range(extra_probes):     # perturb ANOTHER site's stream
            fi.probe("execute")
        fired = []
        for i in range(64):
            try:
                fi.probe("plan", detail=f"k{i}")
                fired.append(0)
            except InjectedFault:
                fired.append(1)
        return fired

    a = pattern(7)
    assert a == pattern(7)                 # reproducible
    assert a == pattern(7, extra_probes=50)  # independent per-site streams
    assert a != pattern(8)                 # seed actually matters
    assert 0 < sum(a) < 64                 # rate is probabilistic, not all


def test_injector_count_after_match_and_disarm():
    fi = FaultInjector(seed=0).arm("execute", count=2, after=1)
    fires = 0
    for _ in range(6):
        try:
            fi.probe("execute")
        except InjectedFault:
            fires += 1
    assert fires == 2                       # bounded by count
    assert fi.summary()["execute"]["probes"] == 6
    assert fi.summary()["execute"]["fired"] == 2

    fi = FaultInjector(seed=0).arm("request", match="req:3")
    fi.probe("request", detail="req:1")     # no match → no fire
    with pytest.raises(InjectedFault) as ei:
        fi.probe("request", detail="req:3")
    assert ei.value.site == "request" and "req:3" in ei.value.detail
    fi.disarm("request")
    fi.probe("request", detail="req:3")     # disarmed → no-op
    assert fi.total_fired == 1


# ----------------------------------------------------- poison isolation
@pytest.mark.parametrize("poison", [0, 3, 5, 7])
def test_poison_request_fails_alone_neighbours_bit_equal(poison):
    """THE isolation property: one injected-fault request fails with the
    injected error; every other request's logits are bit-identical to the
    fault-free run's (every ladder path stays on the pinned program)."""
    fi = FaultInjector(seed=1).arm("request", rate=1.0,
                                   match=f"req:{4 + poison};")
    srv = _serving(faults=fi)
    _warm(srv)                              # warmup ids 0-3, traffic 4-11
    outs = srv.serve((("g", _feats(i)) for i in range(8)),
                     return_exceptions=True)
    assert len(outs) == 8                   # every future resolved
    for i, z in enumerate(outs):
        if i == poison:
            assert isinstance(z, InjectedFault)
        else:
            assert not isinstance(z, Exception)
            np.testing.assert_array_equal(np.asarray(z), ref8()[i])
    assert srv.stats.quarantined == 1
    assert srv.stats.errors == 1
    bad = [r for r in srv.stats.requests if r.error is not None]
    assert len(bad) == 1 and "injected fault" in bad[0].error
    srv.close()


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_property_random_poison_sets_never_fail_neighbours(seed):
    """Seeded sweep over random poison subsets and batch sizes: the failed
    set is EXACTLY the poisoned set, everyone else bit-equal."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 9))
    max_batch = int(rng.integers(2, 5))
    poisons = set(rng.choice(n, size=int(rng.integers(1, 3)),
                             replace=False).tolist())
    fi = FaultInjector(seed=seed)
    for p in poisons:
        fi.arm("request", rate=1.0, match=f"req:{max_batch + p};")
    ref = _reference(n, max_batch=max_batch)
    srv = _serving(faults=fi, max_batch=max_batch)
    _warm(srv, max_batch)
    outs = srv.serve((("g", _feats(i)) for i in range(n)),
                     return_exceptions=True)
    failed = {i for i, z in enumerate(outs) if isinstance(z, Exception)}
    assert failed == poisons
    for i, z in enumerate(outs):
        if i not in poisons:
            np.testing.assert_array_equal(np.asarray(z), ref[i])
    srv.close()


# ----------------------------------------------------- degradation ladder
def test_transient_batch_fault_recovers_bit_equal():
    """A count-bounded batch-level fault (dispatch site, steady state)
    burns out against bisection/retry: zero caller-visible errors, every
    result bit-equal — the whole recovery stayed on the pinned program."""
    fi = FaultInjector(seed=3).arm("dispatch", rate=1.0, count=2, after=1)
    srv = _serving(faults=fi, max_retries=2)
    _warm(srv)                 # after=1 skips the warmup batch's probe
    outs = srv.serve((("g", _feats(i)) for i in range(8)),
                     return_exceptions=True)
    assert not any(isinstance(z, Exception) for z in outs)
    assert srv.stats.errors == 0
    assert srv.stats.bisections + srv.stats.retries >= 1  # ladder engaged
    for i, z in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(z), ref8()[i])
    srv.close()


def test_compiled_fault_degrades_to_eager_batch():
    """A compiled-program failure serves THAT batch on the eager path
    (degraded_batches) and keeps the program.  The eager re-run plans on
    the live operand, so the degraded batch is exact only to FP tolerance
    — batches after it return to the pinned program and bit-equality."""
    fi = FaultInjector(seed=2).arm("compiled", rate=1.0, count=1)
    srv = _serving(faults=fi)
    _warm(srv)
    outs = srv.serve((("g", _feats(i)) for i in range(8)),
                     return_exceptions=True)
    assert not any(isinstance(z, Exception) for z in outs)
    assert srv.stats.degraded_batches == 1
    assert fi.summary()["compiled"]["fired"] == 1
    for i, z in enumerate(outs):
        np.testing.assert_allclose(np.asarray(z), ref8()[i],
                                   rtol=1e-4, atol=1e-5)
    srv.close()


@pytest.mark.parametrize("site", sorted(KNOWN_SITES
                                        - {"snapshot_save", "snapshot_load"}))
def test_chaos_every_site_every_request_resolves(site):
    """One site at a time, a bounded fault at EVERY instrumented serving
    site, with NO pre-warm (so plan/lower/pack/execute probes are hit
    during warmup too): no request may ever be left unanswered, every
    request is recorded, and successful results stay numerically correct.
    (Bit-equality is not asserted here: a mid-warmup fault legitimately
    re-plans on a different operand — the strict gates live in the
    poison-isolation tests above.)"""
    fi = FaultInjector(seed=5).arm(site, rate=1.0, count=2)
    srv = _serving(faults=fi, max_retries=2)
    outs = srv.serve((("g", _feats(i)) for i in range(8)),
                     return_exceptions=True)
    assert len(outs) == 8
    for i, z in enumerate(outs):
        if not isinstance(z, Exception):
            np.testing.assert_allclose(np.asarray(z), ref8()[i],
                                       rtol=1e-4, atol=1e-5)
    assert len(srv.stats.requests) == 8     # all recorded, success or not
    srv.close()


def test_chaos_mixed_sites_all_resolve():
    """Faults armed at several sites at once — the acceptance scenario's
    mixed mode."""
    fi = (FaultInjector(seed=6)
          .arm("plan", rate=0.3, count=2)
          .arm("execute", rate=0.3, count=2)
          .arm("compiled", rate=1.0, count=1)
          .arm("request", rate=1.0, match="req:2;"))
    srv = _serving(faults=fi, max_retries=3)
    outs = srv.serve((("g", _feats(i)) for i in range(8)),
                     return_exceptions=True)
    assert len(outs) == 8
    assert isinstance(outs[2], InjectedFault)       # the poison request
    for i, z in enumerate(outs):
        if i != 2 and not isinstance(z, Exception):
            np.testing.assert_allclose(np.asarray(z), ref8()[i],
                                       rtol=1e-4, atol=1e-5)
    assert len(srv.stats.requests) == 8
    srv.close()


# ------------------------------------------------------------- deadlines
def test_deadline_fails_straggling_request_with_structured_error():
    fi = FaultInjector(seed=4).arm("dispatch", rate=1.0, count=1,
                                   delay_s=1.2)
    srv = _serving(faults=fi, timeout=0.3)
    outs = srv.serve((("g", _feats(i)) for i in range(2)),
                     return_exceptions=True)
    assert all(isinstance(z, DeadlineExceeded) for z in outs)
    assert srv.stats.deadline_expired == 2
    recorded = [r for r in srv.stats.requests
                if r.error and "DeadlineExceeded" in r.error]
    assert len(recorded) == 2
    import time
    time.sleep(1.3)          # let the stalled worker finish before close
    srv.close()


def test_infer_without_deadline_still_works():
    srv = _serving()

    async def go():
        return await srv.infer("g", _feats(0))

    z = asyncio.run(go())
    np.testing.assert_array_equal(np.asarray(z), ref8()[0])
    srv.close()


# -------------------------------------------------------- circuit breaker
def test_breaker_bounds_drift_recompile_churn():
    """Oscillating input density: an unbounded serving loop would
    invalidate/recompile on every flip; the breaker trips after
    ``breaker_threshold`` invalidation events and pins the last-good
    program, so invalidations stay bounded and results stay correct."""
    sparse_h = (RNG.normal(size=(80, 12)) *
                (RNG.uniform(size=(80, 12)) < 0.03)).astype(np.float32)
    dense_h = RNG.normal(size=(80, 12)).astype(np.float32)
    flips = [sparse_h if i % 2 == 0 else dense_h for i in range(12)]

    srv = _serving(max_batch=1, drift=0.25, breaker=(2, 60.0, 60.0))
    outs = srv.serve(("g", h) for h in flips)
    assert srv.stats.breaker_trips >= 1
    # threshold-1 invalidations before the trip, none while pinned
    assert srv.stats.compile_invalidations <= 2
    for h, z in zip(flips, outs):
        ref = gnn.run_reference("GCN", ADJ, jnp.asarray(h), PARAMS)
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
    assert srv.dispatch_stats()["breaker_trips"] == srv.stats.breaker_trips
    srv.close()


# ------------------------------------------------------------ health wire
def test_dispatch_stats_health_surface():
    srv = _serving()
    srv.serve([("g", _feats(i)) for i in range(4)])
    health = srv.dispatch_stats()["health"]
    assert "dispatch-0" in health["hosts"]
    w = health["hosts"]["dispatch-0"]
    assert w["steps"] >= 1 and w["median_step_s"] > 0.0
    assert health["dead"] == [] and "dispatch-0" in health["healthy"]
    srv.close()


def test_fault_monitor_snapshot_flags_dead_and_stragglers():
    mon = FaultMonitor(["a", "b", "x"], timeout=10.0, straggler_factor=2.0)
    t = 100.0
    for i in range(6):
        mon.heartbeat("a", step_time=1.0, now=t + i)
        mon.heartbeat("x", step_time=1.0, now=t + i)
        mon.heartbeat("b", step_time=5.0, now=t + i)
    snap = mon.snapshot(now=t + 6)
    assert snap["stragglers"] == ["b"]
    assert snap["hosts"]["a"]["median_step_s"] == 1.0
    snap = mon.snapshot(now=t + 50)
    assert set(snap["dead"]) == {"a", "b", "x"}
    mon.ensure_host("c", now=t + 50)
    assert "c" in mon.snapshot(now=t + 50)["hosts"]


# ------------------------------------------------- snapshot robustness
def _populated_cache():
    cache = SharedPlanCache()
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    gnn.run_inference("GCN", eng, ADJ, jnp.asarray(_feats(0)), PARAMS)
    cache.register_graph("g", ADJ)
    return cache


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_truncated_snapshot_cold_starts(tmp_path, seed):
    """Truncate a valid snapshot at random offsets: every prefix must load
    as a counted cold start, never an unhandled pickle/EOF error."""
    cache = _populated_cache()
    path = os.fspath(tmp_path / "snap.pkl")
    cache.save(path)
    blob = open(path, "rb").read()
    rng = np.random.default_rng(seed)
    for cut in rng.integers(0, len(blob), size=4):
        with open(path, "wb") as f:
            f.write(blob[:int(cut)])
        fresh = SharedPlanCache()
        manifest = fresh.load(path)
        assert manifest["cold_start"] is True
        assert manifest["entries"] == 0 and len(fresh) == 0
        assert fresh.stats.snapshot_errors == 1
        assert "error" in manifest


def test_garbage_and_wrong_pickle_snapshot_cold_starts(tmp_path):
    path = os.fspath(tmp_path / "snap.pkl")
    with open(path, "wb") as f:
        f.write(b"\x00not a pickle at all" * 7)
    fresh = SharedPlanCache()
    assert fresh.load(path)["cold_start"] is True
    assert fresh.stats.snapshot_errors == 1

    with open(path, "wb") as f:           # valid pickle, wrong payload type
        pickle.dump(["not", "a", "dict"], f)
    manifest = fresh.load(path)
    assert manifest["cold_start"] is True
    assert fresh.stats.snapshot_errors == 2
    assert "not a dict" in manifest["error"]

    missing = os.fspath(tmp_path / "never_written.pkl")
    assert fresh.load(missing)["cold_start"] is True
    assert fresh.stats.snapshot_errors == 3


def test_version_flip_snapshot_cold_starts_with_message(tmp_path):
    cache = _populated_cache()
    path = os.fspath(tmp_path / "snap.pkl")
    cache.save(path)
    payload = pickle.load(open(path, "rb"))
    payload["version"] = 999
    with open(path, "wb") as f:
        pickle.dump(payload, f)
    fresh = SharedPlanCache()
    manifest = fresh.load(path)
    assert manifest["cold_start"] is True
    assert "snapshot version" in manifest["error"]   # recoverable, explicit
    assert fresh.stats.snapshot_errors == 1


def test_fault_during_save_leaves_previous_snapshot_intact(tmp_path):
    """Atomicity: a crash mid-save (injected at the snapshot_save site,
    after the temp file is open) must leave the previous snapshot
    byte-identical and no temp litter behind."""
    cache = _populated_cache()
    path = os.fspath(tmp_path / "snap.pkl")
    cache.save(path)
    good = open(path, "rb").read()

    cache.faults = FaultInjector(seed=9).arm("snapshot_save", rate=1.0)
    with pytest.raises(InjectedFault):
        cache.save(path)
    assert open(path, "rb").read() == good          # old snapshot intact
    assert [p for p in os.listdir(tmp_path)
            if ".tmp." in p] == []                   # no temp litter
    cache.faults = None

    # the intact snapshot still round-trips
    fresh = SharedPlanCache()
    manifest = fresh.load(path)
    assert manifest["cold_start"] is False
    assert manifest["entries"] > 0


def test_injected_snapshot_load_fault_degrades_to_cold_start(tmp_path):
    cache = _populated_cache()
    path = os.fspath(tmp_path / "snap.pkl")
    cache.save(path)
    fresh = SharedPlanCache()
    fresh.faults = FaultInjector(seed=9).arm("snapshot_load", rate=1.0,
                                             count=1)
    manifest = fresh.load(path)
    assert manifest["cold_start"] is True
    assert fresh.stats.snapshot_errors == 1
    # the fault burned out (count=1): the retry loads the real snapshot
    assert fresh.load(path)["cold_start"] is False


def test_corrupt_calibration_snapshot_remeasures(tmp_path, monkeypatch):
    """The calibration snapshot path mirrors the plan cache: garbage on
    disk → counted, logged, re-measured — never an unhandled raise."""
    monkeypatch.delenv(calibrate.SNAPSHOT_ENV, raising=False)
    path = os.fspath(tmp_path / "calib.pkl")
    with open(path, "wb") as f:
        f.write(b"\x80garbage" * 11)
    fake = object()
    monkeypatch.setattr(calibrate, "calibrate", lambda *a, **k: fake)
    cache = PlanCache()
    m = calibrate.get_calibrated(cache, runtime_fallback("cpu"), block=8,
                                 snapshot_path=path)
    assert m is fake                       # fell back to measurement
    assert cache.stats.snapshot_errors == 1


def test_calibration_save_snapshot_is_atomic(tmp_path, monkeypatch):
    base = runtime_fallback("cpu")
    key = calibrate.calibration_key(base, 8, "float32")
    path = os.fspath(tmp_path / "calib.pkl")
    calibrate.save_snapshot(path, {key: "sentinel"})
    good = open(path, "rb").read()

    # a dump that explodes mid-write must not clobber the good file
    class Boom:
        def __reduce__(self):
            raise RuntimeError("mid-pickle crash")

    with pytest.raises(RuntimeError):
        calibrate.save_snapshot(path, {key: Boom()})
    assert open(path, "rb").read() == good
    assert [p for p in os.listdir(tmp_path) if ".tmp." in p] == []
