"""Analyzer property coverage (ISSUE 7 satellite): ``balanced`` never loses
to ``greedy`` under the Scheduler's own makespan model, ``force_queue``
routes every task as documented, and a measured ``CalibratedModel`` with
swapped engine speeds flips STQ/DTQ assignments."""
import dataclasses

import numpy as np
import pytest

from repro.core import analyzer, scheduler
from repro.core.calibrate import CalibratedModel
from repro.core.partition import make_tasks
from repro.core.perfmodel import (TPUV5E, VCK5000, HardwareModel,
                                  runtime_fallback)


def _random_part(rng, name="k"):
    nrt = int(rng.integers(1, 9))
    nct = int(rng.integers(1, 5))
    tm, tn = 64, 32
    K = int(rng.integers(1, 17)) * 64
    row_d = rng.uniform(1e-4, 1.0, size=nrt)
    col_d = rng.uniform(1e-4, 1.0, size=nct)
    return make_tasks(name, nrt * tm, K, nct * tn, row_d, col_d, tm, tn)


def _hw_variants():
    yield VCK5000
    yield TPUV5E
    # stress the LPT-vs-greedy race: few sparse units, tight bandwidth
    yield dataclasses.replace(VCK5000, name="v-1unit", n_sparse_units=1)
    yield dataclasses.replace(VCK5000, name="v-slowmem", mem_bw=1e9)
    yield dataclasses.replace(
        VCK5000, name="v-overhead", dispatch_overhead=1e-5,
        n_sparse_units=2)


@pytest.mark.parametrize("seed", range(8))
def test_property_balanced_never_worse_than_greedy(seed):
    """The ``balanced`` strategy simulates both its LPT placement and the
    per-task greedy rule and returns the better one — so its modeled
    makespan is ≤ greedy's for ANY task set and ANY hardware model."""
    rng = np.random.default_rng(seed)
    for hw in _hw_variants():
        part = _random_part(rng)
        g_stq, g_dtq = analyzer.analyze_kernel(part, hw, "greedy")
        greedy_ms = scheduler.simulate(g_stq, g_dtq, hw).makespan
        b_stq, b_dtq = analyzer.analyze_kernel(part, hw, "balanced")
        balanced_ms = scheduler.simulate(b_stq, b_dtq, hw).makespan
        assert balanced_ms <= greedy_ms * (1 + 1e-12), (hw.name, seed)
        # the returned lists and the task fields agree
        assert all(t.queue == "STQ" for t in b_stq)
        assert all(t.queue == "DTQ" for t in b_dtq)
        assert len(b_stq) + len(b_dtq) == len(part.tasks)


def test_force_queue_routes_every_task():
    rng = np.random.default_rng(3)
    part = _random_part(rng)
    stq, dtq = analyzer.force_queue(part, VCK5000, "STQ")
    assert not dtq and len(stq) == len(part.tasks)
    assert all(t.queue == "STQ" for t in stq)
    assert all(t.primitive in ("SpDMM", "SpMM") for t in stq)
    stq, dtq = analyzer.force_queue(part, VCK5000, "DTQ")
    assert not stq and len(dtq) == len(part.tasks)
    assert all(t.queue == "DTQ" and t.primitive == "GEMM" for t in dtq)


def _calibrated(name, *, gemm_rate, sparse_rate):
    """A CalibratedModel with explicit engine rates (MAC/s) and memory so
    fast that compute decides every assignment."""
    return CalibratedModel(
        name=name, f_dense=1.0, dense_macs_per_cycle=gemm_rate,
        f_sparse=1.0, spdmm_macs_per_cycle=sparse_rate,
        spmm_macs_per_cycle=sparse_rate, n_sparse_units=1,
        mem_bw=1e18, bytes_per_elem=4, dispatch_overhead=0.0,
        skip_block=1, calibrated=True, backend="test", block=8,
        dtype="float32", base="test")


def test_calibrated_swapped_speeds_flip_assignments():
    """Swapping the measured dense/sparse rates of a CalibratedModel must
    flip the greedy STQ/DTQ split: what a fast dense engine claimed, a
    fast sparse engine claims instead."""
    part_args = ("k", 256, 512, 64, [0.5, 0.5, 0.5, 0.5], [0.5], 64, 64)
    fast_dense = _calibrated("cal-dense", gemm_rate=1e12, sparse_rate=1e6)
    stq, dtq = analyzer.analyze_kernel(
        make_tasks(*part_args), fast_dense, "greedy")
    assert not stq and len(dtq) == 4

    fast_sparse = _calibrated("cal-sparse", gemm_rate=1e6, sparse_rate=1e12)
    stq, dtq = analyzer.analyze_kernel(
        make_tasks(*part_args), fast_sparse, "greedy")
    assert not dtq and len(stq) == 4

    # balanced follows the same measurement signal
    stq, dtq = analyzer.analyze_kernel(
        make_tasks(*part_args), fast_sparse, "balanced")
    assert len(stq) == 4 and not dtq


def test_calibrated_model_is_a_hardware_model():
    """CalibratedModel slots into every HardwareModel consumer; provenance
    flags distinguish fitted models from fallback guesses."""
    m = _calibrated("cal", gemm_rate=1e9, sparse_rate=1e9)
    assert isinstance(m, HardwareModel)
    assert m.calibrated and not m.fallback
    assert TPUV5E.fallback and not TPUV5E.calibrated
    assert not VCK5000.fallback
    fb = runtime_fallback("cpu")
    assert fb.fallback and fb.name == "cpu-fallback"
    assert runtime_fallback("tpu") is TPUV5E
