"""Plan/execute split: batched per-queue dispatch equivalence + PlanCache
behaviour (the paper's amortized Alg. 4 preprocessing)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.core.scheduler import ScheduleReport, execute_plan
from repro.core import sparsity
from repro.kernels import ops
from repro.models import gnn

RNG = np.random.default_rng(99)


def _rand_graph(n=80, nnz=240, seed=5):
    """Random adjacency (no duplicate edges) tagged like the data loader's."""
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    rows = (flat // n).astype(np.int32)
    cols = (flat % n).astype(np.int32)
    vals = np.abs(rng.normal(size=nnz)).astype(np.float32)
    return SparseCOO((n, n), jnp.asarray(rows), jnp.asarray(cols),
                     jnp.asarray(vals), tag="adjacency")


# --------------------------------------------------- batched == per-task
@pytest.mark.parametrize("model", gnn.MODELS)
def test_batched_dispatch_matches_pertask_and_reference(model):
    adj = _rand_graph()
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    params = gnn.init_params(model, 12, 8, 5)
    eng_b = DynasparseEngine(tile_m=16, tile_n=8, literal=True, batched=True)
    eng_p = DynasparseEngine(tile_m=16, tile_n=8, literal=True, batched=False)
    z_b, _ = gnn.run_inference(model, eng_b, adj, jnp.asarray(h), params)
    z_p, _ = gnn.run_inference(model, eng_p, adj, jnp.asarray(h), params)
    ref = gnn.run_reference(model, adj, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(z_b), np.asarray(z_p),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(z_b), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_batched_dispatch_mixed_queues_o_primitives_calls():
    """A kernel whose plan lands tasks in all three primitives must execute
    with one pallas launch per primitive, not per task."""
    rng = np.random.default_rng(1)
    xd = rng.normal(size=(96, 64)).astype(np.float32)
    xd[:32] *= (rng.uniform(size=(32, 64)) < 0.01)
    xd[32:64] *= (rng.uniform(size=(32, 64)) < 0.3)
    yd = rng.normal(size=(64, 48)).astype(np.float32)
    yd[:, :24] *= (rng.uniform(size=(64, 24)) < 0.05)
    r, c = np.nonzero(xd)
    x = SparseCOO(xd.shape, jnp.asarray(r.astype(np.int32)),
                  jnp.asarray(c.astype(np.int32)),
                  jnp.asarray(xd[r, c]), tag="adjacency")

    eng = DynasparseEngine(tile_m=32, tile_n=24, literal=True)
    plan = eng.plan(x, jnp.asarray(yd))
    prims = {t.primitive for t in plan.stq} | {t.primitive for t in plan.dtq}
    n_tasks = len(plan.stq) + len(plan.dtq)
    assert prims == {"SpDMM", "SpMM", "GEMM"}, prims

    ops.reset_pallas_call_count()
    z_b = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd, batched=True)
    calls_batched = ops.pallas_call_count()
    ops.reset_pallas_call_count()
    z_p = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd, batched=False)
    calls_pertask = ops.pallas_call_count()

    assert calls_batched == len(prims)       # O(primitives)
    assert calls_pertask == n_tasks          # O(tasks)
    np.testing.assert_allclose(np.asarray(z_b), np.asarray(z_p),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(z_b), xd @ yd, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- cache behaviour
def test_adjacency_packed_and_analyzed_once_across_gcn_layers():
    """2-layer GCN: both aggregation kernels share ONE packing and ONE
    density analysis of the adjacency; a second inference is all plan hits."""
    adj = _rand_graph(n=96, nnz=300, seed=7)
    h = RNG.normal(size=(96, 20)).astype(np.float32)
    params = gnn.init_params("GCN", 20, 16, 16)  # hidden == out: l2 plan hits
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True)

    gnn.run_inference("GCN", eng, adj, jnp.asarray(h), params)
    assert eng.cache.stats.packs == 1
    assert eng.cache.stats.analyzes == 1
    assert eng.cache.stats.plan_hits >= 1    # layer-2 aggregation

    stats_after_first = eng.cache.stats.plan_misses
    z2, _ = gnn.run_inference("GCN", eng, adj, jnp.asarray(h), params)
    assert eng.cache.stats.packs == 1                       # still one packing
    assert eng.cache.stats.analyzes == 1
    assert eng.cache.stats.plan_misses == stats_after_first  # no new misses

    ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_cached_plan_matches_uncached_result():
    """Hitting the cache must not change the numerical result or report."""
    adj = _rand_graph(n=64, nnz=180, seed=3)
    h = RNG.normal(size=(64, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True)
    z1, rep1 = eng.matmul(adj, jnp.asarray(h), name="agg")
    z2, rep2 = eng.matmul(adj, jnp.asarray(h), name="agg")
    assert eng.cache.stats.plan_hits == 1
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    assert rep1.makespan == rep2.makespan


def test_same_pattern_different_values_not_conflated():
    """The fingerprint must cover values: cached packed blocks carry them."""
    adj = _rand_graph(n=64, nnz=150, seed=21)
    h = RNG.normal(size=(64, 8)).astype(np.float32)
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True)
    eng.matmul(adj, jnp.asarray(h))
    doubled = SparseCOO(adj.shape, adj.rows, adj.cols, adj.vals * 2.0,
                        tag="adjacency")
    z, _ = eng.matmul(doubled, jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(z), doubled.todense() @ h,
                               rtol=1e-4, atol=1e-4)
    assert eng.cache.stats.packs == 2


def test_inner_dim_mismatch_raises_at_plan_time():
    adj = _rand_graph(n=64, nnz=150, seed=22)
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True)
    with pytest.raises(ValueError, match="inner-dim mismatch"):
        eng.matmul(adj, jnp.ones((32, 8), jnp.float32))
    assert eng.cache.stats.packs == 0


def test_serving_path_reuses_plans():
    adj = _rand_graph(n=64, nnz=200, seed=11)
    params = gnn.init_params("SGC", 10, 8, 8)
    batches = [RNG.normal(size=(64, 10)).astype(np.float32) for _ in range(3)]
    eng = DynasparseEngine(tile_m=32, tile_n=8)
    outs, reports = gnn.run_serving("SGC", eng, adj, batches, params)
    assert len(outs) == 3 and len(reports) == 3
    # requests 2 and 3 re-plan nothing for the adjacency kernels
    assert eng.cache.stats.plan_hits >= 2 * 2   # 2 agg kernels x 2 requests
    for h, z in zip(batches, outs):
        ref = gnn.run_reference("SGC", adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------- satellites
def test_engine_report_total_empty_is_zero():
    eng = DynasparseEngine()
    eng.reset()
    tot = eng.report.total
    assert isinstance(tot, ScheduleReport)
    assert tot.makespan == 0.0 and tot.n_stq == 0 and tot.flops_executed == 0.0
    assert eng.report.hardware_time == 0.0
    # zero() is merge's identity
    assert ScheduleReport.zero().merge(tot).makespan == 0.0


def test_eps_threads_through_density_helpers():
    """density / stripe_density / tile_density agree on near-zero values."""
    x = np.full((32, 16), 1e-9, dtype=np.float32)
    x[:8] = 1.0
    xj = jnp.asarray(x)
    eps = 1e-6
    d = float(sparsity.density(xj, eps=eps))
    sd = np.asarray(sparsity.stripe_density(xj, 8, axis=0, eps=eps))
    td = np.asarray(sparsity.tile_density(xj, 8, 8, eps=eps))
    assert d == pytest.approx(0.25)
    np.testing.assert_allclose(sd, [1.0, 0.0, 0.0, 0.0])
    assert float(td.mean()) == pytest.approx(0.25)
    # without eps all three report fully dense — they must disagree together,
    # never with each other
    assert float(sparsity.density(xj)) == 1.0
    np.testing.assert_allclose(
        np.asarray(sparsity.stripe_density(xj, 8, axis=0)), [1.0] * 4)


def test_engine_eps_routes_near_zero_stripes_to_sparse_queue():
    x = np.full((64, 64), 1e-9, dtype=np.float32)
    x[:16] = RNG.normal(size=(16, 64)).astype(np.float32)
    y = RNG.normal(size=(64, 8)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, eps=1e-6)
    plan = eng.plan(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(plan.row_density, [1.0, 0.0, 0.0, 0.0])
    z, _ = eng.matmul(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(z), x @ y, rtol=1e-4, atol=1e-4)


def test_coo_row_stripe_density_eps():
    rows = jnp.asarray(np.array([0, 10, 20, 30], dtype=np.int32))
    cols = jnp.asarray(np.zeros(4, dtype=np.int32))
    vals = jnp.asarray(np.array([1.0, 1e-9, 1.0, 1e-9], dtype=np.float32))
    a = SparseCOO((40, 4), rows, cols, vals)
    np.testing.assert_allclose(a.row_stripe_density(10),
                               [1 / 40, 1 / 40, 1 / 40, 1 / 40])
    np.testing.assert_allclose(a.row_stripe_density(10, eps=1e-6),
                               [1 / 40, 0.0, 1 / 40, 0.0])
