"""Serving subsystem: async micro-batched inference equivalence + stats.

The load-bearing property: a micro-batch of k stacked requests produces,
per request, the SAME logits as a per-request ``run_reference`` — the
column-stack / row-unstack transport around the engine kernels never mixes
requests.  Plus: coalescing behaviour, per-request stats, density-drift
replanning, and the run_serving thin-wrapper contract.
"""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.models import gnn
from repro.serving import (ServingConfig, ServingEngine, SharedPlanCache,
                           SketchConfig)

RNG = np.random.default_rng(7)


def _rand_graph(n=80, nnz=240, seed=5):
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=nnz)
                                        ).astype(np.float32)),
                     tag="adjacency")


def _serving(model, params, *, max_batch=4, literal=True,
             drift=0.25, cache=None):
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=literal,
                           cache=cache if cache is not None
                           else SharedPlanCache())
    cfg = ServingConfig(max_batch=max_batch,
                        sketch=SketchConfig(threshold=drift))
    return ServingEngine(model, params, engine=eng, config=cfg)


# ------------------------------------------------------------ equivalence
@pytest.mark.parametrize("model", gnn.MODELS)
def test_micro_batched_matches_per_request_reference(model):
    adj = _rand_graph()
    params = gnn.init_params(model, 12, 8, 5)
    srv = _serving(model, params, max_batch=4)
    srv.register_graph("g", adj)
    batches = [RNG.normal(size=(80, 12)).astype(np.float32)
               for _ in range(6)]
    outs = srv.serve(("g", h) for h in batches)
    assert srv.stats.batches < len(batches)          # actually coalesced
    for h, z in zip(batches, outs):
        ref = gnn.run_reference(model, adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)


def test_coalescing_respects_max_batch_and_records_stats():
    adj = _rand_graph(seed=9)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=4)
    srv.register_graph("g", adj)
    srv.serve(("g", RNG.normal(size=(80, 12)).astype(np.float32))
              for _ in range(10))
    stats = srv.stats
    assert len(stats.requests) == 10
    assert stats.batches == 3                         # 4 + 4 + 2
    assert sorted(r.batch_size for r in stats.requests) == [2, 2] + [4] * 8
    assert all(r.latency >= r.t_queue >= 0.0 for r in stats.requests)
    assert all(r.report is not None for r in stats.requests)
    depths = [r.queue_depth for r in stats.requests]
    assert max(depths) > 0                            # queue actually built up
    pct = stats.latency_percentiles()
    assert pct["p95"] >= pct["p50"] > 0.0


def test_one_plan_execute_pass_per_micro_batch():
    """k coalesced requests must issue ONE engine kernel sequence, not k."""
    adj = _rand_graph(seed=3)
    params = gnn.init_params("GCN", 12, 8, 8)
    srv = _serving("GCN", params, max_batch=8)
    srv.register_graph("g", adj)
    srv.serve(("g", RNG.normal(size=(80, 12)).astype(np.float32))
              for _ in range(8))
    assert srv.stats.batches == 1
    # the shared micro-batch report holds one kernel sequence (4 GCN mms)
    rep = srv.stats.requests[0].report
    assert len(rep.kernels) == 4


def test_multi_graph_requests_do_not_mix():
    adj_a, adj_b = _rand_graph(seed=1), _rand_graph(seed=2)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=4, cache=cache)
    srv.register_graph("a", adj_a)
    srv.register_graph("b", adj_b)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    outs = srv.serve([("a", h), ("b", h), ("a", h)])
    ref_a = gnn.run_reference("GCN", adj_a, jnp.asarray(h), params)
    ref_b = gnn.run_reference("GCN", adj_b, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref_a),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(ref_b),
                               rtol=1e-3, atol=1e-3)
    # same request content ⇒ same answer (up to primitive choice: the
    # balanced strategy may route a tile of one copy to the other queue)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               rtol=1e-5, atol=1e-5)
    assert set(cache.graphs) == {"a", "b"}


def test_unregistered_graph_raises():
    srv = _serving("GCN", gnn.init_params("GCN", 12, 8, 5))
    with pytest.raises(KeyError, match="not registered"):
        asyncio.run(srv.infer("nope", np.zeros((4, 12), np.float32)))


def test_dispatch_error_fails_requests_instead_of_hanging():
    """An engine-side error inside a micro-batch must surface as the
    requests' exception — never strand their futures (serve() deadlock)."""
    adj = _rand_graph(seed=4)
    srv = _serving("GCN", gnn.init_params("GCN", 10, 8, 5), max_batch=2)
    srv.register_graph("g", adj)
    bad = RNG.normal(size=(80, 7)).astype(np.float32)   # fan-in mismatch
    with pytest.raises(ValueError):
        srv.serve([("g", bad), ("g", bad)])


def test_run_serving_restores_engine_drift_settings():
    adj = _rand_graph(seed=5)
    params = gnn.init_params("SGC", 10, 8, 8)
    eng = DynasparseEngine(tile_m=16, tile_n=8)
    assert eng.drift_threshold is None
    gnn.run_serving("SGC", eng, adj,
                    [RNG.normal(size=(80, 10)).astype(np.float32)], params)
    assert eng.drift_threshold is None      # no hidden mutation



# ------------------------------------------------------- density drift
def test_density_drift_triggers_replan_and_matches_reference():
    """Near-dense features swapped mid-stream: the sketch must catch the
    stale cached Y-densities, replan, and the result must stay exact."""
    adj = _rand_graph(seed=11)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=1, cache=cache)
    srv.register_graph("g", adj)

    sparse_h = (RNG.normal(size=(80, 12)) *
                (RNG.uniform(size=(80, 12)) < 0.03)).astype(np.float32)
    dense_h = RNG.normal(size=(80, 12)).astype(np.float32)
    outs = srv.serve([("g", sparse_h), ("g", sparse_h), ("g", dense_h)])

    assert cache.stats.replans > 0                   # drift was caught
    ref = gnn.run_reference("GCN", adj, jnp.asarray(dense_h), params)
    np.testing.assert_allclose(np.asarray(outs[2]), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_no_drift_no_replan():
    adj = _rand_graph(seed=12)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=1, cache=cache)
    srv.register_graph("g", adj)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    srv.serve([("g", h), ("g", h), ("g", h)])
    assert cache.stats.replans == 0
    assert cache.stats.plan_hits > 0                 # amortization intact


# ------------------------------------------------------- wrapper contract
def test_run_serving_wrapper_per_request_and_micro_batched():
    adj = _rand_graph(seed=21)
    params = gnn.init_params("SGC", 10, 8, 8)
    batches = [RNG.normal(size=(80, 10)).astype(np.float32)
               for _ in range(4)]

    outs1, reports1 = gnn.run_serving(
        "SGC", DynasparseEngine(tile_m=16, tile_n=8), adj, batches, params)
    outs4, reports4 = gnn.run_serving(
        "SGC", DynasparseEngine(tile_m=16, tile_n=8), adj, batches, params,
        max_batch=4)
    assert len(outs1) == len(outs4) == len(reports1) == len(reports4) == 4
    for h, z1, z4 in zip(batches, outs1, outs4):
        ref = gnn.run_reference("SGC", adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(z4), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
    # micro-batched: one engine pass for all four requests
    assert reports4[0] is reports4[3]
    assert reports1[0] is not reports1[3]
