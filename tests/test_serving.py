"""Serving subsystem: async micro-batched inference equivalence + stats.

The load-bearing property: a micro-batch of k stacked requests produces,
per request, the SAME logits as a per-request ``run_reference`` — the
column-stack / row-unstack transport around the engine kernels never mixes
requests.  Plus: coalescing behaviour, per-request stats, density-drift
replanning, and the run_serving thin-wrapper contract.
"""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.models import gnn
from repro.serving import (ServingConfig, ServingEngine, SharedPlanCache,
                           SketchConfig)

RNG = np.random.default_rng(7)


def _rand_graph(n=80, nnz=240, seed=5):
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=nnz)
                                        ).astype(np.float32)),
                     tag="adjacency")


def _serving(model, params, *, max_batch=4, literal=True,
             drift=0.25, cache=None, pad=True):
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=literal,
                           cache=cache if cache is not None
                           else SharedPlanCache())
    cfg = ServingConfig(max_batch=max_batch,
                        sketch=SketchConfig(threshold=drift),
                        pad_to_max_batch=pad)
    return ServingEngine(model, params, engine=eng, config=cfg)


# ------------------------------------------------------------ equivalence
@pytest.mark.parametrize("model", gnn.MODELS)
def test_micro_batched_matches_per_request_reference(model):
    adj = _rand_graph()
    params = gnn.init_params(model, 12, 8, 5)
    srv = _serving(model, params, max_batch=4)
    srv.register_graph("g", adj)
    batches = [RNG.normal(size=(80, 12)).astype(np.float32)
               for _ in range(6)]
    outs = srv.serve(("g", h) for h in batches)
    assert srv.stats.batches < len(batches)          # actually coalesced
    for h, z in zip(batches, outs):
        ref = gnn.run_reference(model, adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)


def test_coalescing_respects_max_batch_and_records_stats():
    adj = _rand_graph(seed=9)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=4)
    srv.register_graph("g", adj)
    srv.serve(("g", RNG.normal(size=(80, 12)).astype(np.float32))
              for _ in range(10))
    stats = srv.stats
    assert len(stats.requests) == 10
    assert stats.batches == 3                         # 4 + 4 + 2
    assert sorted(r.batch_size for r in stats.requests) == [2, 2] + [4] * 8
    assert all(r.latency >= r.t_queue >= 0.0 for r in stats.requests)
    assert all(r.report is not None for r in stats.requests)
    depths = [r.queue_depth for r in stats.requests]
    assert max(depths) > 0                            # queue actually built up
    pct = stats.latency_percentiles()
    assert pct["p95"] >= pct["p50"] > 0.0


def test_one_plan_execute_pass_per_micro_batch():
    """k coalesced requests must issue ONE engine kernel sequence, not k."""
    adj = _rand_graph(seed=3)
    params = gnn.init_params("GCN", 12, 8, 8)
    srv = _serving("GCN", params, max_batch=8)
    srv.register_graph("g", adj)
    srv.serve(("g", RNG.normal(size=(80, 12)).astype(np.float32))
              for _ in range(8))
    assert srv.stats.batches == 1
    # the shared micro-batch report holds one kernel sequence (4 GCN mms)
    rep = srv.stats.requests[0].report
    assert len(rep.kernels) == 4


def test_multi_graph_requests_do_not_mix():
    adj_a, adj_b = _rand_graph(seed=1), _rand_graph(seed=2)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=4, cache=cache)
    srv.register_graph("a", adj_a)
    srv.register_graph("b", adj_b)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    outs = srv.serve([("a", h), ("b", h), ("a", h)])
    ref_a = gnn.run_reference("GCN", adj_a, jnp.asarray(h), params)
    ref_b = gnn.run_reference("GCN", adj_b, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref_a),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(ref_b),
                               rtol=1e-3, atol=1e-3)
    # same request content ⇒ same answer (up to primitive choice: the
    # balanced strategy may route a tile of one copy to the other queue)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               rtol=1e-5, atol=1e-5)
    assert set(cache.graphs) == {"a", "b"}


def test_partial_batch_padding_matches_reference():
    """A partial micro-batch (k < max_batch) is padded to the max_batch
    stacked width (replicated columns); the padding must be an exact
    no-op per request."""
    adj = _rand_graph(seed=13)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=8)
    srv.register_graph("g", adj)
    batches = [RNG.normal(size=(80, 12)).astype(np.float32)
               for _ in range(3)]
    outs = srv.serve(("g", h) for h in batches)
    assert srv.stats.batches == 1                     # one padded batch of 3
    assert [r.batch_size for r in srv.stats.requests] == [3, 3, 3]
    for h, z in zip(batches, outs):
        assert z.shape == (80, 5)                     # padding sliced away
        ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)


def test_single_plan_across_batch_sizes():
    """With pad_to_max_batch, serving k ∈ {1..max_batch} must create exactly
    one plan entry per graph/layer kernel — not one per batch size."""
    adj = _rand_graph(seed=14)
    params = gnn.init_params("GCN", 12, 8, 5)   # hidden != out: 2 agg widths
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=4, cache=cache)
    srv.register_graph("g", adj)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
    for k in (1, 2, 3, 4):
        outs = srv.serve([("g", h)] * k)
        for z in outs:
            np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                       rtol=1e-3, atol=1e-3)
    # one plan per aggregation kernel geometry (GCN: l1-agg and l2-agg have
    # different widths), regardless of the four distinct batch sizes
    assert cache.plan_count() == 2

    # without padding, every distinct batch size plans its own width
    cache2 = SharedPlanCache()
    srv2 = _serving("GCN", params, max_batch=4, cache=cache2, pad=False)
    srv2.register_graph("g", adj)
    for k in (1, 2, 3, 4):
        srv2.serve([("g", h)] * k)
    assert cache2.plan_count() == 2 * 4


def test_padded_partial_batches_do_not_thrash_replanner():
    """Mixed full/partial traffic with stable content must trigger ZERO
    density-drift replans: the padding replicates real feature columns, so
    the padded operand's density matches a full batch's (zero-padding here
    would register ~1.0 drift on every fill change and replan per batch,
    defeating single-plan serving)."""
    adj = _rand_graph(seed=19)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=4, cache=cache)   # drift=0.25
    srv.register_graph("g", adj)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    for k in (4, 1, 4, 1, 4):
        srv.serve([("g", h)] * k)
    assert cache.stats.replans == 0
    assert cache.plan_count() == 2            # still one plan per agg kernel


def test_serve_inside_running_loop():
    """serve() must work when the calling thread already runs an event loop
    (notebooks, async callers) — asyncio.run would raise RuntimeError."""
    adj = _rand_graph(seed=15)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=2)
    srv.register_graph("g", adj)
    h = RNG.normal(size=(80, 12)).astype(np.float32)

    async def main():
        return srv.serve([("g", h), ("g", h)])

    outs = asyncio.run(main())
    assert len(outs) == 2
    ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
    for z in outs:
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)


def test_failed_requests_recorded_in_stats():
    """A mixed-width micro-batch is bisected by the degradation ladder: the
    well-formed request is served alone, the poison one fails ALONE with
    `error` recorded — failed traffic may not undercount, and a bad
    neighbour may not take the batch down with it."""
    adj = _rand_graph(seed=16)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=2)
    srv.register_graph("g", adj)
    h_a = RNG.normal(size=(80, 12)).astype(np.float32)
    h_b = RNG.normal(size=(80, 13)).astype(np.float32)   # wrong fan-in
    with pytest.raises(ValueError):
        srv.serve([("g", h_a), ("g", h_b)])
    assert len(srv.stats.requests) == 2
    assert srv.stats.bisections >= 1
    assert srv.stats.errors == 1
    assert srv.stats.quarantined == 1
    bad = [r for r in srv.stats.requests if r.error is not None]
    assert len(bad) == 1 and bad[0].batch_size == 1
    good = [r for r in srv.stats.requests if r.error is None]
    assert len(good) == 1 and good[0].report is not None
    assert srv.stats.as_dict()["errors"] == 1
    # the well-formed request's logits were actually delivered
    outs = srv.serve([("g", h_a), ("g", h_b)], return_exceptions=True)
    assert not isinstance(outs[0], Exception)
    assert isinstance(outs[1], Exception)
    ref = gnn.run_reference("GCN", adj, jnp.asarray(h_a), params)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_error_escaping_dispatch_fails_batch_instead_of_hanging():
    """An exception raised before the engine try-block (here: same widths
    but mismatched row counts, so the stacking concatenate throws) must
    never strand futures: the ladder bisects, serves the well-formed
    request, and quarantines the poison one with its error recorded."""
    adj = _rand_graph(seed=22)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=2)
    srv.register_graph("g", adj)
    h_a = RNG.normal(size=(80, 12)).astype(np.float32)
    h_b = RNG.normal(size=(96, 12)).astype(np.float32)  # wrong row count
    with pytest.raises(Exception):
        srv.serve([("g", h_a), ("g", h_b)])
    assert len(srv.stats.requests) == 2
    assert srv.stats.errors == 1              # poison fails alone
    assert srv.stats.quarantined == 1
    assert len(srv.stats.batch_reports) == 1  # the good half's report


def test_serve_after_close_raises_instead_of_hanging():
    """Submitting against a closed engine must surface the executor's
    RuntimeError through the futures, not deadlock."""
    adj = _rand_graph(seed=23)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=2)
    srv.register_graph("g", adj)
    srv.close()
    with pytest.raises(RuntimeError):
        srv.serve([("g", RNG.normal(size=(80, 12)).astype(np.float32))])
    assert srv.stats.errors == 1


def test_per_request_report_attribution():
    """Each request's report is its 1/k share of the micro-batch report; the
    raw batch report is kept on stats.batch_reports."""
    adj = _rand_graph(seed=17)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=4)
    srv.register_graph("g", adj)
    srv.serve(("g", RNG.normal(size=(80, 12)).astype(np.float32))
              for _ in range(4))
    assert srv.stats.batches == 1
    assert len(srv.stats.batch_reports) == 1
    batch_rep = srv.stats.batch_reports[0]
    assert batch_rep.hardware_time > 0.0
    for r in srv.stats.requests:
        assert r.report.hardware_time == pytest.approx(
            batch_rep.hardware_time / 4)
        assert r.report.total.flops_executed == pytest.approx(
            batch_rep.total.flops_executed / 4)
        # the kernel sequence itself is shared (4 GCN matmuls)
        assert len(r.report.kernels) == len(batch_rep.kernels) == 4
    # shares sum back to the batch total
    assert sum(r.report.hardware_time for r in srv.stats.requests) == (
        pytest.approx(batch_rep.hardware_time))


def test_unregistered_graph_raises():
    srv = _serving("GCN", gnn.init_params("GCN", 12, 8, 5))
    with pytest.raises(KeyError, match="not registered"):
        asyncio.run(srv.infer("nope", np.zeros((4, 12), np.float32)))


def test_dispatch_error_fails_requests_instead_of_hanging():
    """An engine-side error inside a micro-batch must surface as the
    requests' exception — never strand their futures (serve() deadlock)."""
    adj = _rand_graph(seed=4)
    srv = _serving("GCN", gnn.init_params("GCN", 10, 8, 5), max_batch=2)
    srv.register_graph("g", adj)
    bad = RNG.normal(size=(80, 7)).astype(np.float32)   # fan-in mismatch
    with pytest.raises(ValueError):
        srv.serve([("g", bad), ("g", bad)])


def test_run_serving_restores_engine_drift_settings():
    adj = _rand_graph(seed=5)
    params = gnn.init_params("SGC", 10, 8, 8)
    eng = DynasparseEngine(tile_m=16, tile_n=8)
    assert eng.drift_threshold is None
    gnn.run_serving("SGC", eng, adj,
                    [RNG.normal(size=(80, 10)).astype(np.float32)], params)
    assert eng.drift_threshold is None      # no hidden mutation



# ------------------------------------------------- compiled-dispatch path
def test_compiled_serving_steady_state_stats_and_results():
    """After the warmup batch, EVERY micro-batch must run as one compiled
    call (zero descriptor builds, jit trace hits) and still match the
    per-request reference."""
    adj = _rand_graph(seed=31)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=4, cache=cache)
    srv.register_graph("g", adj)
    batches = [RNG.normal(size=(80, 12)).astype(np.float32)
               for _ in range(16)]
    outs = srv.serve(("g", h) for h in batches)
    ds = srv.dispatch_stats()
    assert srv.stats.compiled_batches == srv.stats.batches - 1
    assert ds["dispatch_builds"] == ds["plans"]
    assert ds["replans"] == 0
    assert ds["trace_cache_hits"] > 0
    # every compiled batch after the first reused the whole-model trace
    assert ds["trace_cache_hits"] >= srv.stats.compiled_batches - 1
    for h, z in zip(batches, outs):
        ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
    srv.close()


def test_compile_models_off_keeps_eager_path():
    adj = _rand_graph(seed=32)
    params = gnn.init_params("GCN", 12, 8, 5)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                           cache=SharedPlanCache())
    srv = ServingEngine("GCN", params, engine=eng,
                        config=ServingConfig(max_batch=4,
                                             compile_models=False))
    srv.register_graph("g", adj)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    outs = srv.serve([("g", h)] * 8)
    assert srv.stats.compiled_batches == 0
    ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
    for z in outs:
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
    srv.close()


def test_compiled_drift_invalidation_recompiles():
    """Input-density drift must drop the compiled program, replan through
    the eager pass, recompile, and stay reference-exact."""
    adj = _rand_graph(seed=33)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=1, cache=cache)
    srv.register_graph("g", adj)
    sparse_h = (RNG.normal(size=(80, 12)) *
                (RNG.uniform(size=(80, 12)) < 0.03)).astype(np.float32)
    dense_h = RNG.normal(size=(80, 12)).astype(np.float32)
    outs = srv.serve([("g", sparse_h), ("g", sparse_h),
                      ("g", dense_h), ("g", dense_h)])
    assert srv.stats.compile_invalidations >= 1
    assert cache.stats.replans > 0
    ref = gnn.run_reference("GCN", adj, jnp.asarray(dense_h), params)
    for z in outs[2:]:
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
    srv.close()


def test_reregistered_graph_drops_stale_compiled_program():
    """Re-registering a graph_id with a DIFFERENT adjacency must not keep
    serving the old graph's compiled whole-model program (the input-density
    drift check cannot see an adjacency swap)."""
    adj_a, adj_b = _rand_graph(seed=41), _rand_graph(seed=42)
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = _serving("GCN", params, max_batch=2)
    srv.register_graph("g", adj_a)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    srv.serve([("g", h)] * 4)                    # warm + compile against a
    assert srv.stats.compiled_batches >= 1
    srv.register_graph("g", adj_b)               # swap the graph in place
    outs = srv.serve([("g", h)] * 2)
    ref_b = gnn.run_reference("GCN", adj_b, jnp.asarray(h), params)
    for z in outs:
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref_b),
                                   rtol=1e-3, atol=1e-3)
    srv.close()


def test_graph_scale_sparse_only_serving_never_densifies():
    """The graph-scale x=None batched path THROUGH the ServingEngine: an
    all-sparse plan must serve (compiled included) without ever
    materializing the densified adjacency."""
    adj = _rand_graph(seed=34, n=96, nnz=200)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                           mode="sparse_only", cache=cache)
    srv = ServingEngine("GCN", params, engine=eng,
                        config=ServingConfig(max_batch=4))
    srv.register_graph("g", adj)
    batches = [RNG.normal(size=(96, 12)).astype(np.float32)
               for _ in range(8)]
    outs = srv.serve(("g", h) for h in batches)
    assert srv.stats.compiled_batches >= 1
    from repro.core.plancache import PlanCache, StructureEntry
    entries = [v for (kind, _k), v in cache.items()
               if kind == PlanCache._STRUCT]
    assert entries, "expected packed structure entries"
    assert all(isinstance(e, StructureEntry) and e.dense is None
               for e in entries)
    for h, z in zip(batches, outs):
        ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
    srv.close()


# ------------------------------------------------------- density drift
def test_density_drift_triggers_replan_and_matches_reference():
    """Near-dense features swapped mid-stream: the sketch must catch the
    stale cached Y-densities, replan, and the result must stay exact."""
    adj = _rand_graph(seed=11)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=1, cache=cache)
    srv.register_graph("g", adj)

    sparse_h = (RNG.normal(size=(80, 12)) *
                (RNG.uniform(size=(80, 12)) < 0.03)).astype(np.float32)
    dense_h = RNG.normal(size=(80, 12)).astype(np.float32)
    outs = srv.serve([("g", sparse_h), ("g", sparse_h), ("g", dense_h)])

    assert cache.stats.replans > 0                   # drift was caught
    ref = gnn.run_reference("GCN", adj, jnp.asarray(dense_h), params)
    np.testing.assert_allclose(np.asarray(outs[2]), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_no_drift_no_replan():
    adj = _rand_graph(seed=12)
    params = gnn.init_params("GCN", 12, 8, 5)
    cache = SharedPlanCache()
    srv = _serving("GCN", params, max_batch=1, cache=cache)
    srv.register_graph("g", adj)
    h = RNG.normal(size=(80, 12)).astype(np.float32)
    srv.serve([("g", h), ("g", h), ("g", h)])
    assert cache.stats.replans == 0
    assert cache.stats.plan_hits > 0                 # amortization intact


# ------------------------------------------------------- wrapper contract
def test_run_serving_wrapper_per_request_and_micro_batched():
    adj = _rand_graph(seed=21)
    params = gnn.init_params("SGC", 10, 8, 8)
    batches = [RNG.normal(size=(80, 10)).astype(np.float32)
               for _ in range(4)]

    outs1, reports1 = gnn.run_serving(
        "SGC", DynasparseEngine(tile_m=16, tile_n=8), adj, batches, params)
    outs4, reports4 = gnn.run_serving(
        "SGC", DynasparseEngine(tile_m=16, tile_n=8), adj, batches, params,
        max_batch=4)
    assert len(outs1) == len(outs4) == len(reports1) == len(reports4) == 4
    for h, z1, z4 in zip(batches, outs1, outs4):
        ref = gnn.run_reference("SGC", adj, jnp.asarray(h), params)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(z4), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
    # micro-batched: one engine pass for all four requests — they share one
    # attributed (1/k) report object; per-request runs each get their own
    assert reports4[0] is reports4[3]
    assert reports1[0] is not reports1[3]
