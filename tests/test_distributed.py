"""Fault-tolerance + distributed-substrate tests (CPU, small shapes)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.lm import TokenPipeline
from repro.distributed.elastic import plan_remesh
from repro.distributed.fault import FaultMonitor
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_decompress, ef_init


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "opt": {"step": jnp.int32(7)}}
    mgr = CheckpointManager(tmp_path, cfg={"arch": "x"})
    mgr.save(5, state, blocking=True)
    step, restored = mgr.restore(state)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, jax.tree.map(lambda x: x * s, state))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    _, restored = mgr.restore(state)
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((2,))}
    mgr.save(1, state, blocking=True)
    # simulate a crashed writer
    (tmp_path / "step_000000099.tmp").mkdir()
    assert mgr.latest_step() == 1


def test_checkpoint_config_hash_guard(tmp_path):
    mgr = CheckpointManager(tmp_path, cfg={"arch": "a"})
    mgr.save(1, {"w": jnp.ones(2)}, blocking=True)
    mgr2 = CheckpointManager(tmp_path, cfg={"arch": "DIFFERENT"})
    with pytest.raises(ValueError, match="hash"):
        mgr2.restore({"w": jnp.ones(2)})


def test_checkpoint_restart_training_is_deterministic(tmp_path):
    """Train 6 steps; train 3 + restore + 3: identical final params —
    the checkpoint/restart invariant that makes preemption safe."""
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=6)

    def loss_fn(p, x):
        return jnp.mean((x @ p["w"] - 1.0) ** 2)

    def step(state, x):
        loss, g = jax.value_and_grad(loss_fn)(state["params"], x)
        new_p, new_o, _ = adamw_update(g, state["opt"], state["params"],
                                       opt_cfg)
        return {"params": new_p, "opt": new_o}

    def batch(i):
        return jnp.asarray(
            np.random.default_rng(i).normal(size=(4, 3)).astype(np.float32))

    p0 = {"w": jnp.ones((3,)) * 0.5}
    s = {"params": p0, "opt": adamw_init(p0)}
    for i in range(6):
        s = step(s, batch(i))
    ref = np.asarray(s["params"]["w"])

    s2 = {"params": p0, "opt": adamw_init(p0)}
    mgr = CheckpointManager(tmp_path)
    for i in range(3):
        s2 = step(s2, batch(i))
    mgr.save(3, s2, blocking=True)
    start, s3 = mgr.restore(s2)
    for i in range(start, 6):
        s3 = step(s3, batch(i))
    np.testing.assert_allclose(np.asarray(s3["params"]["w"]), ref, rtol=1e-6)


# ------------------------------------------------------------ compression
def test_compression_error_feedback_converges():
    """int8+EF gradient descent reaches the same optimum as fp32 on a
    quadratic — the error-feedback guarantee."""
    w_true = np.array([1.5, -2.0, 0.5], np.float32)

    def grad(w, rng):
        x = rng.normal(size=(32, 3)).astype(np.float32)
        return ((x @ (w - w_true))[:, None] * x).mean(0) * 2

    rng = np.random.default_rng(0)
    w_fp = jnp.zeros(3)
    w_q = jnp.zeros(3)
    ef = ef_init({"g": w_q})
    for i in range(300):
        g = jnp.asarray(grad(np.asarray(w_fp), rng))
        w_fp = w_fp - 0.05 * g
        g2 = jnp.asarray(grad(np.asarray(w_q), rng))
        gq, ef = compress_decompress({"g": g2}, ef)
        w_q = w_q - 0.05 * gq["g"]
    np.testing.assert_allclose(np.asarray(w_q), w_true, atol=0.1)
    np.testing.assert_allclose(np.asarray(w_fp), w_true, atol=0.1)


def test_compression_quantization_bounded():
    g = {"a": jnp.asarray(np.random.default_rng(1).normal(size=(64,))
                          .astype(np.float32))}
    ef = ef_init(g)
    deq, ef2 = compress_decompress(g, ef)
    err = np.abs(np.asarray(deq["a"]) - np.asarray(g["a"]))
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127.0
    assert err.max() <= scale * 0.51 + 1e-7
    # EF state holds exactly the residual
    np.testing.assert_allclose(np.asarray(ef2["a"]),
                               np.asarray(g["a"]) - np.asarray(deq["a"]),
                               atol=1e-6)


# ------------------------------------------------------------ elastic
def test_elastic_plan_shrinks_data_axis():
    plan = plan_remesh(200, model_parallel=16, original_data=16)
    assert plan.mesh_shape == (8, 16)
    assert plan.n_devices == 128
    assert plan.microbatch_scale == 2


def test_elastic_plan_rejects_too_few():
    with pytest.raises(ValueError):
        plan_remesh(8, model_parallel=16)


# ------------------------------------------------------------ fault
def test_fault_monitor_detects_dead_and_stragglers():
    m = FaultMonitor(["h0", "h1", "h2"], timeout=10, straggler_factor=2.0)
    now = time.monotonic()
    for i in range(8):
        m.heartbeat("h0", 1.0, now=now)
        m.heartbeat("h1", 1.1, now=now)
        m.heartbeat("h2", 5.0, now=now)   # persistent straggler
    assert m.stragglers() == ["h2"]
    assert m.dead_hosts(now=now + 5) == []
    m.heartbeat("h0", now=now + 30)
    m.heartbeat("h2", now=now + 30)
    assert m.dead_hosts(now=now + 30) == ["h1"]
    assert set(m.healthy_hosts(now=now + 30)) == {"h0"}


# ------------------------------------------------------------ data
def test_token_pipeline_deterministic_resume():
    p1 = TokenPipeline(vocab=100, batch=2, seq_len=8, start_step=0)
    batches = [next(p1) for _ in range(4)]
    p1.close()
    p2 = TokenPipeline(vocab=100, batch=2, seq_len=8, start_step=2)
    resumed = next(p2)
    p2.close()
    np.testing.assert_array_equal(resumed["tokens"], batches[2]["tokens"])


def test_token_pipeline_prefetch_nonblocking():
    p = TokenPipeline(vocab=1000, batch=4, seq_len=128, depth=2)
    t0 = time.time()
    next(p)
    next(p)
    assert time.time() - t0 < 5.0
    p.close()
