"""Compiled dispatch (tentpole of ISSUE 4).

A planned kernel is lowered ONCE into a device-resident CompiledDispatch
(sorted descriptor arrays + pooled blocks, vectorized numpy build) and every
later execute is a single jitted call.  These tests pin the load-bearing
properties: bit-identity against BOTH existing paths (eager batched and
per-task) across ragged/mixed-primitive geometries, zero host descriptor
work in steady state, honest cache accounting/eviction, the decline gates
(eps-thresholded SpMM, misaligned canvas), and the whole-model compiler.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.core import dispatch as dispatch_mod
from repro.core.plancache import PlanCache
from repro.core.scheduler import execute_plan
from repro.models import gnn

RNG = np.random.default_rng(31)


def _coo_of(xd: np.ndarray) -> SparseCOO:
    r, c = np.nonzero(xd)
    return SparseCOO(xd.shape, jnp.asarray(r.astype(np.int32)),
                     jnp.asarray(c.astype(np.int32)),
                     jnp.asarray(xd[r, c]), tag="adjacency")


def _mixed_ragged_operands(seed=1, M=90, K=64, N=44):
    """Sparsity bands that land tasks in all three primitives, with ragged
    row and column edge tiles under (tile_m=32, tile_n=24)."""
    rng = np.random.default_rng(seed)
    xd = rng.normal(size=(M, K)).astype(np.float32)
    xd[:32] *= (rng.uniform(size=(32, K)) < 0.01)
    xd[32:64] *= (rng.uniform(size=(32, K)) < 0.3)
    yd = rng.normal(size=(K, N)).astype(np.float32)
    yd[:, :24] *= (rng.uniform(size=(K, 24)) < 0.05)
    return xd, yd


def _all_paths(eng, xd, yd):
    """(compiled, eager batched, per-task) results of one planned kernel."""
    x = _coo_of(xd)
    plan = eng.plan(x, jnp.asarray(yd))
    z_c = eng.execute(plan, x, jnp.asarray(yd))
    z_b = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd, batched=True)
    z_p = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd, batched=False)
    return plan, np.asarray(z_c), np.asarray(z_b), np.asarray(z_p)


def test_compiled_mixed_primitives_ragged_bitwise():
    xd, yd = _mixed_ragged_operands()
    eng = DynasparseEngine(tile_m=32, tile_n=24, literal=True)
    plan, z_c, z_b, z_p = _all_paths(eng, xd, yd)
    prims = {t.primitive for t in plan.stq} | {t.primitive for t in plan.dtq}
    assert prims == {"SpDMM", "SpMM", "GEMM"}, prims
    assert eng.cache.stats.dispatch_builds == 1   # compiled path was taken
    np.testing.assert_array_equal(z_c, z_b)
    np.testing.assert_array_equal(z_c, z_p)
    np.testing.assert_allclose(z_c, xd @ yd, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tm,tn,mkn,seed", [
    (16, 8, (40, 32, 20), 7),     # ragged both axes
    (32, 8, (64, 48, 8), 3),      # single col stripe
    (8, 16, (24, 16, 33), 11),    # ragged col tail
    (128, 128, (20, 16, 5), 5),   # single padded slot
])
def test_compiled_bit_identity_across_geometries(tm, tn, mkn, seed):
    M, K, N = mkn
    rng = np.random.default_rng(seed)
    xd = (rng.normal(size=(M, K)) *
          (rng.uniform(size=(M, K)) < 0.3)).astype(np.float32)
    yd = (rng.normal(size=(K, N)) *
          (rng.uniform(size=(K, N)) < 0.5)).astype(np.float32)
    eng = DynasparseEngine(tile_m=tm, tile_n=tn, literal=True)
    _, z_c, z_b, z_p = _all_paths(eng, xd, yd)
    np.testing.assert_array_equal(z_c, z_b)
    np.testing.assert_array_equal(z_c, z_p)
    np.testing.assert_allclose(z_c, xd @ yd, rtol=1e-4, atol=1e-4)


def test_steady_state_builds_nothing_and_hits_trace():
    """Second execute of the same plan: descriptor build count frozen, the
    dispatch is a cache hit, the jit trace is a hit, result identical."""
    xd, yd = _mixed_ragged_operands(seed=2)
    x = _coo_of(xd)
    eng = DynasparseEngine(tile_m=32, tile_n=24, literal=True)
    z1, _ = eng.matmul(x, jnp.asarray(yd))
    s = eng.cache.stats
    builds = s.dispatch_builds
    assert builds == 1

    # any attempt to lower descriptors again (or run per-block Python
    # loops) in steady state is the regression this PR removes
    def _boom(*a, **k):
        raise AssertionError("descriptor build ran on a plan-cache hit")
    orig = dispatch_mod.build_dispatch
    dispatch_mod.build_dispatch = _boom
    try:
        z2, _ = eng.matmul(x, jnp.asarray(yd))
    finally:
        dispatch_mod.build_dispatch = orig
    assert s.dispatch_builds == builds
    assert s.dispatch_hits >= 1
    assert s.trace_cache_hits >= 1
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


@pytest.mark.parametrize("eps", [1e-7, 0.2])
def test_eps_spmm_compiles_bit_identically(eps):
    """Regression (ISSUE 5): eps != 0 with SpMM tasks used to DECLINE
    compilation and silently stay eager.  The eps-aware masked pairing
    (sub-eps Y blocks zeroed inside the traced program) lifts the gate:
    such plans now compile and the compiled result is bit-identical to
    both eager paths under the same eps."""
    xd, yd = _mixed_ragged_operands(seed=4)
    x = _coo_of(xd)
    eng = DynasparseEngine(tile_m=32, tile_n=24, literal=True, eps=eps)
    plan = eng.plan(x, jnp.asarray(yd))
    if not any(t.primitive == "SpMM" for t in plan.stq):
        pytest.skip("plan routed no SpMM tasks")
    assert eng.dispatch_for(plan, x) is not None
    z_c = eng.execute(plan, x, jnp.asarray(yd))
    assert eng.cache.stats.dispatch_builds == 1
    z_b = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                       batched=True, eps=eps)
    z_p = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                       batched=False, eps=eps)
    np.testing.assert_array_equal(np.asarray(z_c), np.asarray(z_b))
    np.testing.assert_array_equal(np.asarray(z_c), np.asarray(z_p))
    if eps <= 1e-6:     # tolerance below the operands' magnitude floor:
        np.testing.assert_allclose(np.asarray(z_c), xd @ yd,   # == dense
                                   rtol=1e-4, atol=1e-4)


def test_misaligned_geometry_declines_compiled_but_matches():
    """tile_m=12 interior boundaries can't take the in-place index maps:
    no dispatch is built and execution falls through the existing paths."""
    rng = np.random.default_rng(3)
    xd = (rng.normal(size=(36, 24)) *
          (rng.uniform(size=(36, 24)) < 0.3)).astype(np.float32)
    yd = rng.normal(size=(24, 16)).astype(np.float32)
    x = _coo_of(xd)
    eng = DynasparseEngine(tile_m=12, tile_n=8, literal=True)
    plan = eng.plan(x, jnp.asarray(yd))
    assert eng.dispatch_for(plan, x) is None
    z, _ = eng.matmul(x, jnp.asarray(yd))
    assert eng.cache.stats.dispatch_builds == 0
    np.testing.assert_allclose(np.asarray(z), xd @ yd, rtol=1e-4, atol=1e-4)


def test_dispatch_entries_byte_accounted_and_evictable():
    """A cached dispatch must charge its descriptor/pool bytes and obey the
    LRU byte budget like every other entry kind."""
    xd, yd = _mixed_ragged_operands(seed=6)
    x = _coo_of(xd)
    eng = DynasparseEngine(tile_m=32, tile_n=24, literal=True)
    before = eng.cache.bytes_used
    eng.matmul(x, jnp.asarray(yd))
    assert eng.cache.dispatch_count() == 1
    assert eng.cache.bytes_used > before

    small = PlanCache(max_bytes=1)      # everything but the newest evicts
    eng2 = DynasparseEngine(tile_m=32, tile_n=24, literal=True, cache=small)
    eng2.matmul(x, jnp.asarray(yd))
    assert small.stats.evictions > 0
    assert small.bytes_used <= max(
        nb for _, nb in small._entries.values()) or len(small) == 1


def test_replan_same_assignment_reuses_dispatch():
    """The dispatch key is content-addressed on (structure, assignment):
    a drift replan that lands on the same task assignment must HIT."""
    xd, yd = _mixed_ragged_operands(seed=8)
    x = _coo_of(xd)
    eng = DynasparseEngine(tile_m=32, tile_n=24, literal=True,
                           drift_threshold=1e-12)  # replan on any wiggle
    eng.matmul(x, jnp.asarray(yd))
    assert eng.cache.stats.dispatch_builds == 1
    # zero ONE element of a dense stripe: a sub-eps density wiggle that
    # trips the replan threshold but cannot flip any task's assignment
    yd2 = yd.copy()
    r, c = np.argwhere(yd2[:, 24:] != 0)[0]
    yd2[r, 24 + c] = 0.0
    eng.matmul(x, jnp.asarray(yd2))
    assert eng.cache.stats.replans >= 1
    assert eng.cache.stats.dispatch_builds == 1     # reused, not rebuilt
    assert eng.cache.stats.dispatch_hits >= 1


# --------------------------------------------------------- compile_model
@pytest.mark.parametrize("model", gnn.MODELS)
def test_compile_model_single_program_matches_eager(model):
    rng = np.random.default_rng(17)
    n, nnz = 80, 240
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    adj = SparseCOO((n, n), jnp.asarray((flat // n).astype(np.int32)),
                    jnp.asarray((flat % n).astype(np.int32)),
                    jnp.asarray(np.abs(rng.normal(size=nnz)
                                       ).astype(np.float32)),
                    tag="adjacency")
    h = rng.normal(size=(n, 12)).astype(np.float32)
    params = gnn.init_params(model, 12, 8, 5)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    eng.reset()
    warm, cm = gnn.compile_model(model, eng, adj, jnp.asarray(h), params)
    assert cm is not None
    assert cm.n_sparse >= 1
    assert len(cm.report.kernels) == cm.n_kernels
    ref = gnn.run_reference(model, adj, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(warm), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
    z1 = cm(jnp.asarray(h))
    z2 = cm(jnp.asarray(h))
    assert cm.calls == 2 and cm.traces == 1        # one trace, then hits
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    np.testing.assert_allclose(np.asarray(z1), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_compile_model_declines_on_nonliteral_engine():
    rng = np.random.default_rng(19)
    n, nnz = 40, 80
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    adj = SparseCOO((n, n), jnp.asarray((flat // n).astype(np.int32)),
                    jnp.asarray((flat % n).astype(np.int32)),
                    jnp.asarray(np.abs(rng.normal(size=nnz)
                                       ).astype(np.float32)),
                    tag="adjacency")
    h = rng.normal(size=(n, 10)).astype(np.float32)
    params = gnn.init_params("SGC", 10, 8, 8)
    eng = DynasparseEngine(tile_m=16, tile_n=8)     # literal=False
    warm, cm = gnn.compile_model("SGC", eng, adj, jnp.asarray(h), params)
    assert cm is None
    ref = gnn.run_reference("SGC", adj, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(warm), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
