"""Hypothesis-driven property sweeps (optional dev dependency).

``pytest.importorskip`` keeps the tier-1 suite collecting when ``hypothesis``
is absent; the deterministic kernel/layer cases live in ``test_kernels.py``
and ``test_layers.py`` and always run.  The interpret-mode Pallas sweeps are
marked ``slow`` and excluded from the default fast lane (see pytest.ini).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.formats import pack_blockcsr
from repro.models.layers import flash_attention


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    nrb=st.integers(1, 4), ncb=st.integers(1, 4), nnb=st.integers(1, 3),
    da=st.floats(0.0, 1.0), dy=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_sparse_kernels_match_dense(nrb, ncb, nnb, da, dy, seed):
    """Invariant: spdmm/spmm equal the dense product for ANY block pattern."""
    block = 8
    rng = np.random.default_rng(seed)
    m, k, n = nrb * block, ncb * block, nnb * block
    am = (rng.uniform(size=(nrb, ncb)) < da).astype(np.float32)
    ym = (rng.uniform(size=(ncb, nnb)) < dy).astype(np.float32)
    a_dense = (rng.normal(size=(m, k)) * np.kron(am, np.ones((block, block)))
               ).astype(np.float32)
    y_dense = (rng.normal(size=(k, n)) * np.kron(ym, np.ones((block, block)))
               ).astype(np.float32)
    a = pack_blockcsr(a_dense, block)
    y_sp = pack_blockcsr(y_dense, block)
    want = a_dense @ y_dense
    got_spdmm = ops.spdmm(a, jnp.asarray(y_dense), bn=8, interpret=True)
    got_spmm = ops.spmm(a, y_sp, interpret=True)
    np.testing.assert_allclose(np.asarray(got_spdmm), want, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_spmm), want, rtol=2e-4, atol=2e-3)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(
    M=st.integers(9, 70), K=st.integers(8, 48), N=st.integers(4, 40),
    tm=st.sampled_from([8, 16, 24, 32]), tn=st.sampled_from([8, 12, 16]),
    dx=st.floats(0.02, 0.9), dy=st.floats(0.02, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_compiled_eager_pertask_bit_identity(M, K, N, tm, tn,
                                                      dx, dy, seed):
    """Invariant (ISSUE 4): for ANY ragged/non-aligned geometry and operand
    sparsity, the engine's compiled dispatch, the eager batched path and the
    per-task path produce bit-identical results.  Misalignable tile sizes
    (tm=24, tn=12) exercise the decline-and-fall-back route."""
    from repro.core import DynasparseEngine, SparseCOO
    from repro.core.scheduler import execute_plan

    rng = np.random.default_rng(seed)
    xd = (rng.normal(size=(M, K)) *
          (rng.uniform(size=(M, K)) < dx)).astype(np.float32)
    yd = (rng.normal(size=(K, N)) *
          (rng.uniform(size=(K, N)) < dy)).astype(np.float32)
    r, c = np.nonzero(xd)
    x = SparseCOO(xd.shape, jnp.asarray(r.astype(np.int32)),
                  jnp.asarray(c.astype(np.int32)),
                  jnp.asarray(xd[r, c]), tag="adjacency")
    eng = DynasparseEngine(tile_m=tm, tile_n=tn, literal=True,
                           interpret=True)
    plan = eng.plan(x, jnp.asarray(yd))
    z_c = np.asarray(eng.execute(plan, x, jnp.asarray(yd)))
    z_b = np.asarray(execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                                  batched=True, interpret=True))
    z_p = np.asarray(execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                                  batched=False, interpret=True))
    np.testing.assert_array_equal(z_c, z_b)
    np.testing.assert_array_equal(z_c, z_p)
    np.testing.assert_allclose(z_c, xd @ yd, rtol=2e-4, atol=2e-3)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    M=st.integers(9, 70), K=st.integers(8, 48), N=st.integers(4, 40),
    tm=st.sampled_from([8, 16, 32]), tn=st.sampled_from([8, 16, 24]),
    bd=st.floats(0.0, 0.6), dy=st.floats(0.02, 1.0),
    eps=st.sampled_from([0.0, 0.05]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    capmode=st.sampled_from(["auto", "exact", "slack", "overflow"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_activation_skip_bit_identity(M, K, N, tm, tn, bd, dy, eps,
                                               dtype, capmode, seed):
    """Invariant (ISSUE 5): for ANY ragged geometry, activation block
    pattern, dtype, eps, and capacity within budget, the compiled capacity
    block-skip route is bit-identical to the eager batched AND per-task
    paths; a capacity below the need flips the in-program overflow fallback
    to the plain dense GEMM (bit-identical to that route instead)."""
    from repro.core import DynasparseEngine
    from repro.core import dispatch as dispatch_mod
    from repro.core.scheduler import execute_plan
    from repro.kernels import ops as kops

    if dtype == "bfloat16":
        import ml_dtypes
        np_dtype = ml_dtypes.bfloat16
    else:
        np_dtype = np.float32
    rng = np.random.default_rng(seed)
    B = 8
    nrb, ncb = -(-M // B), -(-K // B)
    mask = (rng.uniform(size=(nrb, ncb)) < bd).astype(np.float32)
    xd = ((rng.normal(size=(nrb * B, ncb * B))
           * np.kron(mask, np.ones((B, B))))[:M, :K]).astype(np_dtype)
    yd = (rng.normal(size=(K, N)) *
          (rng.uniform(size=(K, N)) < dy)).astype(np.float32)
    eng = DynasparseEngine(tile_m=tm, tile_n=tn, literal=True,
                           interpret=True, eps=eps)
    plan = eng.plan(xd, jnp.asarray(yd))
    if not plan.stq:
        return                                    # dense wins: no route
    need = dispatch_mod.activation_capacity(xd, plan.part, B, eps=eps,
                                            slack=1.0)
    if need is None:
        return                                    # misaligned canvas
    cap = {"auto": None, "exact": need, "slack": need + 3,
           "overflow": max(1, need - 1)}[capmode]
    ad = eng.activation_dispatch_for(plan, xd, capacity=cap)
    assert ad is not None
    z_a, diag = dispatch_mod.execute_activation(ad, xd, yd, interpret=True)
    z_a = np.asarray(z_a)
    if capmode == "overflow" and need > 1:
        assert bool(diag["overflow"])
        z_d = kops.gemm(jnp.asarray(xd), jnp.asarray(yd), interpret=True,
                        out_dtype=jnp.float32)
        np.testing.assert_array_equal(z_a, np.asarray(z_d))
        return
    assert not bool(diag["overflow"])
    z_b = np.asarray(execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                                  batched=True, interpret=True, eps=eps))
    z_p = np.asarray(execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                                  batched=False, interpret=True, eps=eps))
    np.testing.assert_array_equal(z_a, z_b)
    np.testing.assert_array_equal(z_a, z_p)
    if eps == 0.0:
        np.testing.assert_allclose(
            z_a, np.asarray(xd, np.float32) @ yd, rtol=2e-2, atol=2e-2)


def _naive_attention(q, k, v, causal=False):
    B, Lq, Hq, Dh = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(np.float32).reshape(B, Lq, Hkv, G, Dh)
    s = np.einsum("bqhgd,bkhd->bhgqk", qf, np.asarray(k, np.float32))
    s /= np.sqrt(Dh)
    if causal:
        mask = np.arange(Lk)[None, :] <= np.arange(Lq)[:, None]
        s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bhgqk,bkhd->bqhgd", p, np.asarray(v, np.float32))
    return out.reshape(B, Lq, Hq, Dh)


@settings(max_examples=10, deadline=None)
@given(lq=st.integers(1, 33), lk=st.integers(1, 33), seed=st.integers(0, 999))
def test_property_flash_attention_ragged(lq, lk, seed):
    """Invariant: flash == naive for arbitrary (non-chunk-aligned) lengths,
    cross-attention style."""
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(1, lq, 2, 8)).astype(np.float32)
    k = rng.normal(size=(1, lk, 2, 8)).astype(np.float32)
    v = rng.normal(size=(1, lk, 2, 8)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=False, q_chunk=8, kv_chunk=8)
    want = _naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3, atol=5e-3)
