"""Multi-device SPMD tests — run in a subprocess with 8 host devices so the
main test process keeps seeing 1 device (per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, dataclasses, json
    from repro.configs import ARCHS
    from repro.configs.reduced import reduce_config
    from repro.models.registry import build_model
    from repro.launch.mesh import make_mesh_for_devices
    from repro.launch.steps import init_state, make_train_step
    from repro.distributed.sharding import params_shardings, batch_shardings
    from repro.optim.adamw import AdamWConfig

    out = {}

    # ---- 1) sharded train step == single-device train step (phi3 reduced)
    cfg = dataclasses.replace(reduce_config(ARCHS["phi3-mini-3.8b"]),
                              d_model=64, n_layers=2, microbatches=2)
    bundle = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                   jnp.int32)}
    step = make_train_step(bundle, AdamWConfig(lr=1e-3, warmup_steps=0))

    state1 = init_state(bundle)
    s1, m1 = jax.jit(step)(state1, batch)

    mesh = make_mesh_for_devices(8, model_parallel=2)
    with mesh:
        state2 = init_state(bundle)
        p_sh = params_shardings(state2["params"], mesh)
        b_sh = batch_shardings(batch, mesh)
        state2 = dict(state2,
                      params=jax.device_put(state2["params"], p_sh))
        s2, m2 = jax.jit(step, in_shardings=(None, b_sh))(state2, batch)
    out["loss_single"] = float(m1["loss"])
    out["loss_sharded"] = float(m2["loss"])
    w1 = np.asarray(jax.tree.leaves(s1["params"])[0], np.float32)
    w2 = np.asarray(jax.tree.leaves(s2["params"])[0], np.float32)
    out["params_maxdiff"] = float(np.abs(w1 - w2).max())

    # ---- 2) pipeline parallelism equivalence
    from repro import compat
    from repro.distributed.pipeline import pipeline_apply
    pmesh = compat.make_mesh((4,), ("pipe",))
    def stage_fn(w, x):
        return jnp.tanh(x @ w)
    ws = jnp.asarray(rng.normal(size=(4, 16, 16)).astype(np.float32)) * 0.5
    xs = jnp.asarray(rng.normal(size=(6, 3, 16)).astype(np.float32))
    got = pipeline_apply(pmesh, stage_fn, ws, xs)
    want = xs
    for s in range(4):
        want = jnp.tanh(want @ ws[s])
    out["pipe_maxdiff"] = float(jnp.abs(got - want).max())

    # ---- 3) int8 psum via shard_map
    from repro.optim.compression import psum8
    from jax.sharding import PartitionSpec as P
    dmesh = compat.make_mesh((8,), ("data",))
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    f = compat.shard_map(lambda v: psum8(v, "data"), mesh=dmesh,
                         in_specs=P("data"), out_specs=P(), check=False)
    got8 = np.asarray(f(x))[0]
    want8 = np.asarray(x.sum(0))
    # worst-case quantization budget: n_ranks x 0.5 ulp x shared scale
    budget = 8 * 0.5 * float(np.abs(np.asarray(x)).max()) / 127.0
    out["psum8_err_over_budget"] = float(np.abs(got8 - want8).max() / budget)

    # ---- 4) elastic: restore a checkpoint onto a SMALLER mesh
    from repro.checkpoint import CheckpointManager
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, s2, blocking=True)
        small = make_mesh_for_devices(4, model_parallel=2)
        with small:
            sh_small = {"params": params_shardings(state2["params"], small),
                        "opt": None}
            stp, restored = mgr.restore(
                {"params": s2["params"], "opt": s2["opt"]},
                shardings={"params": sh_small["params"], "opt": None})
        w3 = np.asarray(jax.tree.leaves(restored["params"])[0], np.float32)
        out["elastic_maxdiff"] = float(np.abs(w3 - w2).max())
        out["elastic_ndev"] = len(set(
            d for l in jax.tree.leaves(restored["params"])
            for d in l.sharding.device_set))
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


def test_sharded_training_matches_single_device(spmd_results):
    r = spmd_results
    assert abs(r["loss_single"] - r["loss_sharded"]) < 1e-3
    # bf16 compute reassociates across shards; tolerance reflects that
    assert r["params_maxdiff"] < 5e-3


def test_pipeline_parallel_matches_serial(spmd_results):
    assert spmd_results["pipe_maxdiff"] < 1e-5


def test_int8_psum_close_to_fp32(spmd_results):
    assert spmd_results["psum8_err_over_budget"] < 1.0


def test_elastic_reshard_preserves_values(spmd_results):
    assert spmd_results["elastic_maxdiff"] == 0.0
    assert spmd_results["elastic_ndev"] == 4


# ---------------------------------------------------------------------------
# Sharded compiled dispatch (DynasparseEngine mesh= path): property-based
# bit-identity on forced 4/8-host-device meshes.  Uses hypothesis when
# installed (CI does); otherwise the pinned deterministic sweep below still
# covers ragged stripe counts, mixed STQ/DTQ, eps-thresholded SpMM and
# stripe counts not divisible by the device count.
_GNN_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import DynasparseEngine
    from repro.core import scheduler as _scheduler
    from repro.core.primitives import SparseCOO
    from repro.launch.mesh import make_data_mesh
    from repro.serving.cache import SharedPlanCache

    MESHES = {nd: make_data_mesh(nd) for nd in (1, 4, 8)}

    def graph(n, nnz, seed):
        r = np.random.default_rng(seed)
        rows = np.sort(r.integers(0, n, nnz)).astype(np.int32)
        cols = r.integers(0, n, nnz).astype(np.int32)
        vals = r.standard_normal(nnz).astype(np.float32)
        return SparseCOO((n, n), jnp.asarray(rows), jnp.asarray(cols),
                         jnp.asarray(vals), tag="adjacency")

    def dense_y(n, w, seed, zero_frac):
        r = np.random.default_rng(seed + 1)
        y = r.standard_normal((n, w)).astype(np.float32)
        if zero_frac:
            y = np.where(r.random((n, w)) < zero_frac, 0.0, y)
        return y.astype(np.float32)

    out = {"cases": 0, "exec_mismatch": 0, "mesh1_mismatch": 0,
           "invariant_mismatch": 0, "saw_mixed": 0, "saw_spmm": 0,
           "saw_nondivisible": 0, "saw_ragged": 0,
           "halo_mismatch": 0, "saw_halo_exchange": 0, "saw_empty_halo": 0,
           "saw_sparse_only_x_none": 0, "diag_exchanged_blocks": 0,
           "diag_cases": 0}

    def check(n, tm, tn, w, nnz, mode, strategy, eps, y_zero, seed,
              adj=None, oracle=False, diag=False):
        adj = adj if adj is not None else graph(n, nnz, seed)
        y = dense_y(n, w, seed, y_zero)
        ref = DynasparseEngine(tile_m=tm, tile_n=tn, literal=True,
                               mode=mode, strategy=strategy, eps=eps)
        z_ref = np.asarray(ref.matmul(adj, y)[0])
        # per-band analysis may legitimately re-decide STQ/DTQ relative to
        # the global analysis (each device has its own engines) — only
        # banding-INVARIANT configs promise end-to-end bitwise equality at
        # every mesh size; mesh size 1 and the executor itself always do
        invariant = mode != "dynamic" or strategy == "greedy"
        for nd in (1, 4, 8):
            eng = DynasparseEngine(tile_m=tm, tile_n=tn, literal=True,
                                   mode=mode, strategy=strategy, eps=eps,
                                   mesh=MESHES[nd])
            z = np.asarray(eng.matmul(adj, y)[0])
            plan = eng.last_plan
            assert eng.cache.sharded_count() <= 1
            if plan.part.n_row_tiles % nd:
                out["saw_nondivisible"] += 1
            if n % tm:
                out["saw_ragged"] += 1
            qs = {t.queue for t in plan.stq + plan.dtq}
            if qs == {"STQ", "DTQ"}:
                out["saw_mixed"] += 1
            if any(t.primitive == "SpMM" for t in plan.stq):
                out["saw_spmm"] += 1
            if not plan.dtq:
                out["saw_sparse_only_x_none"] += 1
            # halo introspection: did this case exchange anything?
            sd = eng.sharded_dispatch_for(plan, adj)
            if sd is not None and sd.halo is not None:
                if sd.halo.max_take > 0:
                    out["saw_halo_exchange"] += 1
                elif nd > 1:
                    out["saw_empty_halo"] += 1
                if diag and nd > 1:
                    out["diag_exchanged_blocks"] += int(sd.halo.max_take)
            # halo vs replicated: same plan, two operand distributions,
            # bitwise-equal results (replicated is the correctness oracle)
            if oracle:
                eng_r = DynasparseEngine(tile_m=tm, tile_n=tn, literal=True,
                                         mode=mode, strategy=strategy,
                                         eps=eps, mesh=MESHES[nd],
                                         operand_sharding="replicate")
                z_r = np.asarray(eng_r.matmul(adj, y)[0])
                if not (z == z_r).all():
                    out["halo_mismatch"] += 1
            # core property: the sharded compiled executor is bit-identical
            # to the single-device EAGER executor on the SAME placed plan
            key, entry = eng._packed_structure(plan, adj)
            xd = (eng._ensure_dense(key, entry, adj)
                  if plan.dtq else None)
            z_e = np.asarray(_scheduler.execute_plan(
                plan.part, plan.stq, plan.dtq, xd, y, block=eng.block,
                interpret=eng.interpret, batched=True,
                packed=entry.stripes, eps=eps))
            if not (z == z_e).all():
                out["exec_mismatch"] += 1
            if nd == 1 and not (z == z_ref).all():
                out["mesh1_mismatch"] += 1
            if invariant and not (z == z_ref).all():
                out["invariant_mismatch"] += 1
        out["cases"] += 1
        if diag:
            out["diag_cases"] += 1

    # pinned anchors: ragged tails, 7 stripes over 4/8 devices, dense-ish
    # mixed-queue graphs, eps-thresholded SpMM (sparse Y), forced queues
    PINNED = [
        (100, 16, 8, 12, 400, "dynamic", "balanced", 0.0, 0.0, 1),
        (100, 16, 8, 12, 400, "dynamic", "greedy", 0.0, 0.0, 2),
        (64, 8, 8, 4, 2000, "dynamic", "balanced", 0.0, 0.0, 3),
        (64, 8, 8, 4, 2000, "dynamic", "greedy", 0.5, 0.8, 4),
        (40, 8, 16, 20, 60, "sparse_only", "balanced", 0.0, 0.8, 5),
        (129, 16, 8, 8, 800, "dense_only", "balanced", 0.0, 0.0, 6),
        (17, 8, 8, 8, 40, "dynamic", "balanced", 0.5, 0.5, 7),
        (56, 8, 8, 8, 900, "sparse_only", "balanced", 0.5, 0.8, 8),
    ]
    for case in PINNED:
        check(*case, oracle=True)

    # empty-halo anchor: a block-diagonal adjacency (every edge stays inside
    # its own row block) never reads a neighbour's rows — the static
    # exchange schedule must contain ZERO blocks at every mesh size, and
    # the result must still match the replicated oracle bitwise.  Also the
    # sparse-only (x=None) coverage anchor: mode forces the whole kernel
    # onto STQ so no dense X operand exists at all.
    def diag_graph(n, tm, seed):
        r = np.random.default_rng(seed)
        m = n * 6
        rows = np.sort(r.integers(0, n, m)).astype(np.int32)
        offs = r.integers(0, tm, m).astype(np.int32)
        cols = np.minimum((rows // tm) * tm + offs, n - 1).astype(np.int32)
        vals = r.standard_normal(m).astype(np.float32)
        return SparseCOO((n, n), jnp.asarray(rows), jnp.asarray(cols),
                         jnp.asarray(vals), tag="adjacency")

    check(64, 8, 8, 8, 0, "sparse_only", "greedy", 0.0, 0.0, 42,
          adj=diag_graph(64, 8, 42), oracle=True, diag=True)

    # heterogeneous per-device cost models: a 2x slower device must get a
    # SMALLER row-band than under the homogeneous default, and the result
    # stays bitwise-equal (banding only moves work, never changes math for
    # banding-invariant modes)
    import dataclasses as _dc
    from repro.core.perfmodel import VCK5000
    slow = _dc.replace(VCK5000, name="vck5000-half",
                       f_dense=VCK5000.f_dense / 2,
                       f_sparse=VCK5000.f_sparse / 2,
                       mem_bw=VCK5000.mem_bw / 2)
    adj_h = graph(256, 4000, 77)
    y_h = dense_y(256, 16, 77, 0.0)
    eng_homog = DynasparseEngine(tile_m=8, tile_n=8, literal=True,
                                 mode="sparse_only", strategy="greedy",
                                 mesh=MESHES[4])
    eng_hetero = DynasparseEngine(tile_m=8, tile_n=8, literal=True,
                                  mode="sparse_only", strategy="greedy",
                                  mesh=MESHES[4],
                                  per_device_models=[VCK5000, slow,
                                                     VCK5000, VCK5000])
    z_homog = np.asarray(eng_homog.matmul(adj_h, y_h)[0])
    z_hetero = np.asarray(eng_hetero.matmul(adj_h, y_h)[0])
    out["homog_bands"] = list(eng_homog.last_plan.placement.band_sizes())
    out["hetero_bands"] = list(eng_hetero.last_plan.placement.band_sizes())
    out["hetero_bitwise"] = int((z_homog == z_hetero).all())

    try:
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st
    except Exception:
        out["engine"] = "pinned-sweep"
    else:
        @settings(max_examples=10, deadline=None, database=None,
                  derandomize=True,
                  suppress_health_check=list(HealthCheck))
        @given(n=st.integers(17, 120), tm=st.sampled_from([8, 16, 32]),
               tn=st.sampled_from([8, 16]), w=st.integers(4, 24),
               deg=st.integers(1, 12),
               mode=st.sampled_from(["dynamic", "sparse_only",
                                     "dense_only"]),
               strategy=st.sampled_from(["balanced", "greedy"]),
               eps=st.sampled_from([0.0, 0.5]),
               y_zero=st.sampled_from([0.0, 0.8]),
               seed=st.integers(0, 10_000))
        def prop(n, tm, tn, w, deg, mode, strategy, eps, y_zero, seed):
            check(n, tm, tn, w, max(1, n * deg), mode, strategy, eps,
                  y_zero, seed)
        prop()
        out["engine"] = "hypothesis"

    # snapshot for the cross-device-count restart test: a mesh-8 sharded
    # dispatch saved here is loaded by the OUTER 1-device test process
    snap = os.environ.get("SHARD_SNAP_PATH")
    if snap:
        cache = SharedPlanCache()
        eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                               cache=cache, mesh=MESHES[8])
        adj = graph(96, 400, 123)
        y = dense_y(96, 8, 123, 0.0)
        eng.matmul(adj, y)
        cache.register_graph("g8", adj)
        manifest = cache.save(snap)
        out["snap_entries"] = manifest["entries"]
        out["snap_sharded"] = cache.sharded_count()
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def gnn_shard_results(tmp_path_factory):
    snap = str(tmp_path_factory.mktemp("shard_snap") / "snapshot.pkl")
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")),
               SHARD_SNAP_PATH=snap)
    proc = subprocess.run([sys.executable, "-c", _GNN_SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):]), snap


def test_sharded_executor_bit_identity(gnn_shard_results):
    """Sharded compiled execute == single-device eager execute of the SAME
    placed plan, bitwise, on meshes of 1/4/8 forced host devices."""
    r, _ = gnn_shard_results
    assert r["cases"] >= 8
    assert r["exec_mismatch"] == 0


def test_mesh_size_one_is_degenerate_case(gnn_shard_results):
    """Mesh size 1 goes through the SAME shard_map code path and lands
    bit-identical to today's single-device engine, end to end."""
    r, _ = gnn_shard_results
    assert r["mesh1_mismatch"] == 0


def test_banding_invariant_modes_bitwise_across_meshes(gnn_shard_results):
    """Forced-queue modes and the greedy per-task rule are banding-invariant
    → end-to-end bitwise equality at every mesh size."""
    r, _ = gnn_shard_results
    assert r["invariant_mismatch"] == 0


def test_property_sweep_coverage(gnn_shard_results):
    """The sweep genuinely exercised the corners the regression targets."""
    r, _ = gnn_shard_results
    assert r["saw_mixed"] > 0          # mixed STQ/DTQ assignments
    assert r["saw_spmm"] > 0           # eps-thresholded / sparse-Y SpMM
    assert r["saw_nondivisible"] > 0   # stripes not divisible by devices
    assert r["saw_ragged"] > 0         # ragged last stripe
    assert r["saw_sparse_only_x_none"] > 0  # no dense X operand at all


def test_halo_matches_replicated_oracle(gnn_shard_results):
    """Owned+halo operand distribution is bitwise-identical to the
    replicate-everything oracle on the same placed plan, meshes 1/4/8 —
    and the sweep genuinely exchanged halo blocks (not all-empty)."""
    r, _ = gnn_shard_results
    assert r["halo_mismatch"] == 0
    assert r["saw_halo_exchange"] > 0


def test_block_diagonal_graph_exchanges_nothing(gnn_shard_results):
    """A block-diagonal adjacency has no cross-band edges: the static
    exchange schedule must be empty (zero blocks, zero ppermute rounds) at
    every mesh size > 1, while results still match the oracle bitwise."""
    r, _ = gnn_shard_results
    assert r["diag_cases"] >= 1
    assert r["diag_exchanged_blocks"] == 0
    assert r["saw_empty_halo"] > 0


def test_heterogeneous_models_shift_band_split(gnn_shard_results):
    """per_device_models= feeds the band DP genuinely different cost
    models: a 2x slower device gets a strictly smaller row-band than under
    the homogeneous default, with bitwise-equal results (banding moves
    work, not math, in banding-invariant modes)."""
    r, _ = gnn_shard_results
    homog, hetero = r["homog_bands"], r["hetero_bands"]
    assert sum(hetero) == sum(homog)   # all stripes still placed
    assert hetero[1] < homog[1]        # the slow device (index 1) shrank
    assert r["hetero_bitwise"] == 1


def test_mesh8_snapshot_safe_on_one_device(gnn_shard_results):
    """A SharedPlanCache snapshot saved on an 8-device host loads safely at
    a smaller device count: the 8-device sharded dispatch is skipped
    (reported in the manifest), and the restored cache still serves a fresh
    engine bit-identically to a cold one.  (In the CI ``multidev`` lane the
    outer process itself has 8 devices, so the entry loads instead — both
    directions of the restart contract are covered across lanes.)"""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import DynasparseEngine
    from repro.core.primitives import SparseCOO
    from repro.serving.cache import SharedPlanCache

    r, snap = gnn_shard_results
    assert r.get("snap_sharded", 0) >= 1   # the snapshot really has one

    cache = SharedPlanCache()
    manifest = cache.load(snap)
    if len(jax.devices()) < 8:
        assert manifest["mesh_skipped"] >= 1
    else:
        assert manifest["mesh_skipped"] == 0
    assert manifest["stale_skipped"] == 0

    # same graph the subprocess snapshotted (same seeds)
    rng = np.random.default_rng(123)
    n, nnz = 96, 400
    rows = np.sort(rng.integers(0, n, nnz)).astype(np.int32)
    cols = rng.integers(0, n, nnz).astype(np.int32)
    vals = rng.standard_normal(nnz).astype(np.float32)
    adj = SparseCOO((n, n), jnp.asarray(rows), jnp.asarray(cols),
                    jnp.asarray(vals), tag="adjacency")
    y = np.random.default_rng(124).standard_normal((n, 8)).astype(np.float32)

    warm = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    z_warm = np.asarray(warm.matmul(adj, y)[0])
    cold = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    z_cold = np.asarray(cold.matmul(adj, y)[0])
    assert (z_warm == z_cold).all()
