"""Multi-device SPMD tests — run in a subprocess with 8 host devices so the
main test process keeps seeing 1 device (per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, dataclasses, json
    from repro.configs import ARCHS
    from repro.configs.reduced import reduce_config
    from repro.models.registry import build_model
    from repro.launch.mesh import make_mesh_for_devices
    from repro.launch.steps import init_state, make_train_step
    from repro.distributed.sharding import params_shardings, batch_shardings
    from repro.optim.adamw import AdamWConfig

    out = {}

    # ---- 1) sharded train step == single-device train step (phi3 reduced)
    cfg = dataclasses.replace(reduce_config(ARCHS["phi3-mini-3.8b"]),
                              d_model=64, n_layers=2, microbatches=2)
    bundle = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                   jnp.int32)}
    step = make_train_step(bundle, AdamWConfig(lr=1e-3, warmup_steps=0))

    state1 = init_state(bundle)
    s1, m1 = jax.jit(step)(state1, batch)

    mesh = make_mesh_for_devices(8, model_parallel=2)
    with mesh:
        state2 = init_state(bundle)
        p_sh = params_shardings(state2["params"], mesh)
        b_sh = batch_shardings(batch, mesh)
        state2 = dict(state2,
                      params=jax.device_put(state2["params"], p_sh))
        s2, m2 = jax.jit(step, in_shardings=(None, b_sh))(state2, batch)
    out["loss_single"] = float(m1["loss"])
    out["loss_sharded"] = float(m2["loss"])
    w1 = np.asarray(jax.tree.leaves(s1["params"])[0], np.float32)
    w2 = np.asarray(jax.tree.leaves(s2["params"])[0], np.float32)
    out["params_maxdiff"] = float(np.abs(w1 - w2).max())

    # ---- 2) pipeline parallelism equivalence
    from repro import compat
    from repro.distributed.pipeline import pipeline_apply
    pmesh = compat.make_mesh((4,), ("pipe",))
    def stage_fn(w, x):
        return jnp.tanh(x @ w)
    ws = jnp.asarray(rng.normal(size=(4, 16, 16)).astype(np.float32)) * 0.5
    xs = jnp.asarray(rng.normal(size=(6, 3, 16)).astype(np.float32))
    got = pipeline_apply(pmesh, stage_fn, ws, xs)
    want = xs
    for s in range(4):
        want = jnp.tanh(want @ ws[s])
    out["pipe_maxdiff"] = float(jnp.abs(got - want).max())

    # ---- 3) int8 psum via shard_map
    from repro.optim.compression import psum8
    from jax.sharding import PartitionSpec as P
    dmesh = compat.make_mesh((8,), ("data",))
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    f = compat.shard_map(lambda v: psum8(v, "data"), mesh=dmesh,
                         in_specs=P("data"), out_specs=P(), check=False)
    got8 = np.asarray(f(x))[0]
    want8 = np.asarray(x.sum(0))
    # worst-case quantization budget: n_ranks x 0.5 ulp x shared scale
    budget = 8 * 0.5 * float(np.abs(np.asarray(x)).max()) / 127.0
    out["psum8_err_over_budget"] = float(np.abs(got8 - want8).max() / budget)

    # ---- 4) elastic: restore a checkpoint onto a SMALLER mesh
    from repro.checkpoint import CheckpointManager
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, s2, blocking=True)
        small = make_mesh_for_devices(4, model_parallel=2)
        with small:
            sh_small = {"params": params_shardings(state2["params"], small),
                        "opt": None}
            stp, restored = mgr.restore(
                {"params": s2["params"], "opt": s2["opt"]},
                shardings={"params": sh_small["params"], "opt": None})
        w3 = np.asarray(jax.tree.leaves(restored["params"])[0], np.float32)
        out["elastic_maxdiff"] = float(np.abs(w3 - w2).max())
        out["elastic_ndev"] = len(set(
            d for l in jax.tree.leaves(restored["params"])
            for d in l.sharding.device_set))
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


def test_sharded_training_matches_single_device(spmd_results):
    r = spmd_results
    assert abs(r["loss_single"] - r["loss_sharded"]) < 1e-3
    # bf16 compute reassociates across shards; tolerance reflects that
    assert r["params_maxdiff"] < 5e-3


def test_pipeline_parallel_matches_serial(spmd_results):
    assert spmd_results["pipe_maxdiff"] < 1e-5


def test_int8_psum_close_to_fp32(spmd_results):
    assert spmd_results["psum8_err_over_budget"] < 1.0


def test_elastic_reshard_preserves_values(spmd_results):
    assert spmd_results["elastic_maxdiff"] == 0.0
    assert spmd_results["elastic_ndev"] == 4
