"""Placement layer + mesh plumbing units that run on ONE device.

The multi-device behaviour (shard_map execution on forced 4/8-device hosts)
lives in tests/test_sharding_multidev.py; everything here exercises the
plan-side machinery — band partitioning, two-level (device, queue)
assignment, per-device reporting, mesh validation, plan-key separation and
the mesh-size-1 degenerate engine — without touching XLA_FLAGS.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import DynasparseEngine, SparseCOO
from repro.core import analyzer as _analyzer
from repro.core import scheduler as _scheduler
from repro.core.partition import DevicePlacement, band_partition, make_tasks
from repro.core.perfmodel import VCK5000
from repro.launch.mesh import make_data_mesh, make_mesh_for_devices
from repro.serving import SharedPlanCache
from repro.serving.engine import ServingConfig, ServingEngine


def _rand_graph(n=96, nnz=500, seed=0):
    r = np.random.default_rng(seed)
    rows = np.sort(r.integers(0, n, nnz)).astype(np.int32)
    cols = r.integers(0, n, nnz).astype(np.int32)
    vals = r.standard_normal(nnz).astype(np.float32)
    return SparseCOO((n, n), jnp.asarray(rows), jnp.asarray(cols),
                     jnp.asarray(vals), tag="adjacency")


# ------------------------------------------------------------ band_partition
def test_band_partition_balances_uniform_loads():
    loads = np.ones((4, 8))
    assert band_partition(loads, 4) == (0, 2, 4, 6, 8)


def test_band_partition_is_min_makespan():
    """DP result is never worse than any brute-forced contiguous split."""
    rng = np.random.default_rng(1)
    loads = rng.random((3, 7))
    starts = band_partition(loads, 3)
    cost = max(loads[d, starts[d]:starts[d + 1]].sum() for d in range(3))
    best = min(
        max(loads[0, :a].sum(), loads[1, a:b].sum(), loads[2, b:].sum())
        for a in range(8) for b in range(a, 8))
    assert cost <= best + 1e-12


def test_band_partition_heterogeneous_devices_shift_the_split():
    # device 1 is 4x slower: it should get a smaller band
    loads = np.ones((2, 8))
    loads[1] *= 4.0
    starts = band_partition(loads, 2)
    sizes = (starts[1] - starts[0], starts[2] - starts[1])
    assert sizes[0] > sizes[1]


def test_band_partition_more_devices_than_stripes():
    starts = band_partition(np.ones((5, 2)), 5)
    placement = DevicePlacement(5, starts)
    assert placement.n_row_tiles == 2
    assert sum(placement.band_sizes()) == 2


def test_band_partition_rejects_bad_shape():
    with pytest.raises(ValueError, match="n_devices, n_stripes"):
        band_partition(np.ones(4), 2)


# ---------------------------------------------------------- DevicePlacement
def test_device_placement_validation_and_lookup():
    p = DevicePlacement(3, (0, 2, 2, 5))
    assert p.n_row_tiles == 5
    assert p.band_sizes() == (2, 0, 3)
    assert [p.device_of(s) for s in range(5)] == [0, 0, 2, 2, 2]
    assert list(p.stripes_of(1)) == []
    with pytest.raises(ValueError, match="malformed"):
        DevicePlacement(2, (0, 5))
    with pytest.raises(ValueError, match="monotone"):
        DevicePlacement(2, (0, 3, 2))
    with pytest.raises(ValueError, match="outside"):
        p.device_of(5)


# ----------------------------------------------------------- analyze_sharded
def _part(nrt=6, nct=2, tm=8, tn=8):
    rng = np.random.default_rng(3)
    return make_tasks("k", nrt * tm, 64, nct * tn,
                      rng.random(nrt), rng.random(nct), tm, tn)


def test_analyze_sharded_covers_every_task_once():
    part = _part()
    stq, dtq, placement = _analyzer.analyze_sharded(
        part, [VCK5000] * 3)
    assert len(stq) + len(dtq) == len(part.tasks)
    for t in stq + dtq:
        assert t.device == placement.device_of(t.i)


def test_analyze_sharded_one_device_matches_analyze_kernel():
    part = _part()
    stq_s, dtq_s, placement = _analyzer.analyze_sharded(part, [VCK5000])
    stq, dtq = _analyzer.analyze_kernel(_part(), VCK5000, "balanced")
    assert placement.band_starts == (0, part.n_row_tiles)
    key = lambda ts: sorted((t.i, t.j, t.queue, t.primitive) for t in ts)
    assert key(stq_s) == key(stq) and key(dtq_s) == key(dtq)


def test_analyze_sharded_rejects_bad_inputs():
    with pytest.raises(ValueError, match="at least one"):
        _analyzer.analyze_sharded(_part(), [])
    with pytest.raises(ValueError, match="unknown mode"):
        _analyzer.analyze_sharded(_part(), [VCK5000], mode="nope")


# ---------------------------------------------------------- simulate_sharded
def test_simulate_sharded_per_device_reports():
    part = _part()
    hws = [VCK5000] * 2
    stq, dtq, placement = _analyzer.analyze_sharded(part, hws)
    rep = _scheduler.simulate_sharded(stq, dtq, placement, hws)
    assert len(rep.per_device) == 2
    assert rep.makespan == max(r.makespan for r in rep.per_device)
    assert rep.flops_executed == pytest.approx(
        sum(r.flops_executed for r in rep.per_device))
    with pytest.raises(ValueError, match="hardware models"):
        _scheduler.simulate_sharded(stq, dtq, placement, hws[:1])


def test_schedule_report_merge_pads_per_device():
    a = _scheduler.ScheduleReport.zero()
    hws = [VCK5000] * 2
    stq, dtq, placement = _analyzer.analyze_sharded(_part(), hws)
    rep = _scheduler.simulate_sharded(stq, dtq, placement, hws)
    merged = a.merge(rep)
    assert len(merged.per_device) == 2
    scaled = rep.scaled(0.5)
    assert scaled.per_device[0].makespan == pytest.approx(
        rep.per_device[0].makespan * 0.5)


# ----------------------------------------------------- mesh-1 engine parity
def test_mesh_size_one_engine_matches_plain_engine():
    """On this 1-device host, mesh=make_data_mesh(1) runs the sharded code
    path end to end and must be bit-identical to the plain engine."""
    adj = _rand_graph()
    y = np.random.default_rng(4).standard_normal((96, 8)).astype(np.float32)
    plain = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    mesh1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                             mesh=make_data_mesh(1))
    z_p = np.asarray(plain.matmul(adj, y)[0])
    z_m = np.asarray(mesh1.matmul(adj, y)[0])
    assert (z_p == z_m).all()
    assert mesh1.cache.sharded_count() == 1
    # the mesh engine reports a per-device breakdown
    rep = mesh1.report
    assert len(rep.by_device) == 1
    assert rep.by_device[0].makespan == pytest.approx(rep.total.makespan)


def test_mesh_engine_plan_keys_are_separate():
    """Mesh and non-mesh engines sharing one cache must not alias plans —
    the mesh plan carries a placement the plain executor doesn't expect."""
    cache = SharedPlanCache()
    adj = _rand_graph(seed=5)
    y = np.random.default_rng(5).standard_normal((96, 8)).astype(np.float32)
    plain = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    mesh1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache,
                             mesh=make_data_mesh(1))
    plain.matmul(adj, y)
    assert plain.last_plan.placement is None
    mesh1.matmul(adj, y)
    assert mesh1.last_plan.placement is not None
    assert cache.plan_count() == 2


def test_mesh_plan_digest_depends_on_geometry():
    """plan_digest must separate placements so a sharded dispatch compiled
    for one banding can never be replayed against another."""
    import dataclasses

    from repro.core.dispatch import plan_digest

    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                           mesh=make_data_mesh(1))
    adj = _rand_graph(seed=6)
    y = np.random.default_rng(6).standard_normal((96, 8)).astype(np.float32)
    eng.matmul(adj, y)
    plan = eng.last_plan
    nrt = plan.part.n_row_tiles
    other = dataclasses.replace(
        plan, placement=DevicePlacement(2, (0, 0, nrt)))
    unplaced = dataclasses.replace(plan, placement=None)
    digests = {plan_digest(p, eng.block) for p in (plan, other, unplaced)}
    assert len(digests) == 3


def test_mesh_engine_rejects_non_data_axes():
    mesh = make_mesh_for_devices(1)   # axes ("data", "model")
    with pytest.raises(ValueError):
        DynasparseEngine(mesh=mesh)


# -------------------------------------------------------------- mesh factory
def test_make_data_mesh_validates():
    with pytest.raises(ValueError, match=">= 1"):
        make_data_mesh(0)
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        make_data_mesh(len(jax.devices()) + 1)
    mesh = make_data_mesh(1)
    assert mesh.axis_names == ("data",)


def test_make_mesh_for_devices_validates():
    with pytest.raises(ValueError, match="positive"):
        make_mesh_for_devices(0)
    with pytest.raises(ValueError, match="not divisible"):
        make_mesh_for_devices(3, model_parallel=2)


# ------------------------------------------------------------------ serving
def test_serving_config_n_devices_one_device():
    from repro.models import gnn
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = ServingEngine("GCN", params,
                        config=ServingConfig(max_batch=2, n_devices=1),
                        cache=SharedPlanCache())
    assert srv.engine.n_devices == 1
    assert srv.engine.mesh is not None
    assert srv.dispatch_stats()["n_devices"] == 1


def test_serving_config_n_devices_conflict():
    from repro.models import gnn
    params = gnn.init_params("GCN", 12, 8, 5)
    eng = DynasparseEngine(literal=True)   # 1 "device", no mesh
    with pytest.raises(ValueError, match="conflicts"):
        ServingEngine("GCN", params, engine=eng,
                      config=ServingConfig(max_batch=2, n_devices=2))


# ------------------------------------------------- operand sharding / halo
def test_operand_sharding_validated_and_cache_keyed():
    """Bad mode rejected up front; halo and replicate engines sharing one
    cache produce bitwise-equal results from two distinct sharded entries
    (the mode is part of the dispatch cache key)."""
    with pytest.raises(ValueError, match="operand_sharding"):
        DynasparseEngine(mesh=make_data_mesh(1), operand_sharding="bogus")

    cache = SharedPlanCache()
    adj = _rand_graph(seed=10)
    y = np.random.default_rng(10).standard_normal((96, 8)).astype(np.float32)
    eh = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache,
                          mesh=make_data_mesh(1))   # halo is the default
    er = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache,
                          mesh=make_data_mesh(1),
                          operand_sharding="replicate")
    zh = np.asarray(eh.matmul(adj, y)[0])
    zr = np.asarray(er.matmul(adj, y)[0])
    assert (zh == zr).all()
    assert cache.sharded_count() == 2
    acct = cache.sharded_operand_bytes()
    assert acct["entries"] == 2
    assert acct["owned_bytes"] > 0


def test_per_device_models_requires_mesh_and_matching_length():
    import dataclasses

    slow = dataclasses.replace(VCK5000, name="vck5000-half",
                               f_dense=VCK5000.f_dense / 2)
    with pytest.raises(ValueError, match="requires a mesh"):
        DynasparseEngine(per_device_models=[VCK5000])
    with pytest.raises(ValueError, match="one model per mesh device"):
        DynasparseEngine(mesh=make_data_mesh(1),
                         per_device_models=[VCK5000, slow])


def test_per_device_models_distinct_plan_key():
    """Heterogeneous model names join the plan key: a default and a
    per-device-model engine sharing one cache coexist as two plans (in a
    model-invariant mode the math is identical, so results stay
    bitwise-equal — only the cache keys differ)."""
    import dataclasses

    cache = SharedPlanCache()
    adj = _rand_graph(seed=11)
    y = np.random.default_rng(11).standard_normal((96, 8)).astype(np.float32)
    slow = dataclasses.replace(VCK5000, name="vck5000-half",
                               f_dense=VCK5000.f_dense / 2,
                               f_sparse=VCK5000.f_sparse / 2)
    e1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache,
                          mode="sparse_only", strategy="greedy",
                          mesh=make_data_mesh(1))
    e2 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache,
                          mode="sparse_only", strategy="greedy",
                          mesh=make_data_mesh(1), per_device_models=[slow])
    z1 = np.asarray(e1.matmul(adj, y)[0])
    z2 = np.asarray(e2.matmul(adj, y)[0])
    assert (z1 == z2).all()
    assert cache.plan_count() == 2


def test_make_production_mesh_is_deprecated_shim():
    """The fixed-shape factory now warns, validates the device count up
    front (instead of mis-sharding at first use), and names the single-host
    multi-pod impossibility explicitly."""
    from repro.launch.mesh import make_production_mesh

    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="needs 256 devices"):
            make_production_mesh()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="single host"):
            make_production_mesh(multi_pod=True)


def test_serving_reports_operand_sharding_stats():
    from repro.models import gnn
    params = gnn.init_params("GCN", 12, 8, 5)
    srv = ServingEngine("GCN", params,
                        config=ServingConfig(max_batch=2, n_devices=1),
                        cache=SharedPlanCache())
    st = srv.dispatch_stats()
    assert st["operand_sharding"] == "halo"
    assert "operand_bytes" in st
