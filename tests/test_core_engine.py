"""End-to-end behaviour tests for the paper's runtime system."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynasparseEngine, SparseCOO, VCK5000, TPUV5E
from repro.core.analyzer import analyze_kernel, force_queue
from repro.core.partition import make_tasks
from repro.core.perfmodel import (TaskShape, t_dense, t_spdmm, t_spmm,
                                  t_sparse, flops, data_count)
from repro.core.scheduler import simulate
from repro.core import sparsity

RNG = np.random.default_rng(7)


def _coo(m, n, nnz, seed=0):
    rng = np.random.default_rng(seed)
    # sample without replacement: adjacency matrices have no duplicate edges
    flat = np.sort(rng.choice(m * n, size=nnz, replace=False))
    rows = (flat // n).astype(np.int32)
    cols = (flat % n).astype(np.int32)
    vals = rng.normal(size=nnz).astype(np.float32)
    return SparseCOO((m, n), jnp.asarray(rows), jnp.asarray(cols),
                     jnp.asarray(vals))


# ------------------------------------------------------------- perf model
def test_perfmodel_table1_closed_forms():
    """Check the Table I formulas verbatim on VCK5000 constants."""
    task = TaskShape(m=512, n=512, d=64, alpha_x=0.01, alpha_y=1.0)
    hw = VCK5000
    macs = 512 * 512 * 64
    # GEMM: mnd / (f_AIE * N_AIE * beta)
    expect_dense = macs / (1e9 * 128 * 8)
    got = t_dense(task, hw)
    assert got >= expect_dense  # memory bound can only increase it
    compute_only = macs / (hw.f_dense * hw.dense_macs_per_cycle)
    assert np.isclose(compute_only, expect_dense)
    # SpDMM: alpha_min * mnd / (f_PL * p * q)
    expect_spdmm = 0.01 * macs / (297e6 * 32)
    got_compute = 0.01 * macs / (hw.f_sparse * hw.spdmm_macs_per_cycle)
    assert np.isclose(got_compute, expect_spdmm)
    # SpMM: alpha_X*alpha_Y*mnd / (f_PL * p)
    expect_spmm = 0.01 * 1.0 * macs / (297e6 * 8)
    got_spmm = 0.01 * 1.0 * macs / (hw.f_sparse * hw.spmm_macs_per_cycle)
    assert np.isclose(got_spmm, expect_spmm)


def test_analyzer_prefers_sparse_engine_for_sparse_tasks():
    """α→0 ⇒ sparse queue; α→1 ⇒ dense queue (the paper's core decision)."""
    part = make_tasks("k", 1024, 1024, 128, [0.001, 1.0], [1.0], 512, 128)
    stq, dtq = analyze_kernel(part, VCK5000)
    by_alpha = {t.shape.alpha_x: t for t in part.tasks}
    assert by_alpha[0.001].queue == "STQ"
    assert by_alpha[1.0].queue == "DTQ"
    assert len(stq) + len(dtq) == 2


def test_spmm_beats_spdmm_when_both_sparse():
    t = TaskShape(m=512, n=512, d=512, alpha_x=0.01, alpha_y=0.01)
    ts, prim = t_sparse(t, VCK5000)
    # SpMM work: 1e-4*mnd/8 < SpDMM work: 1e-2*mnd/32
    assert prim == "SpMM"
    t2 = TaskShape(m=512, n=512, d=512, alpha_x=0.01, alpha_y=1.0)
    _, prim2 = t_sparse(t2, VCK5000)
    assert prim2 == "SpDMM"


def test_flops_and_data_accounting_monotone():
    t = TaskShape(m=256, n=256, d=64, alpha_x=0.1, alpha_y=0.5)
    assert flops(t, "SpMM") <= flops(t, "SpDMM") <= flops(t, "GEMM")
    assert data_count(t, "SpDMM") <= data_count(t, "GEMM")


# ------------------------------------------------------------- scheduler
def test_scheduler_balances_sparse_units():
    # α must be below the engine-ratio break-even (~0.0093 on VCK5000:
    # AIE 1024 MAC/cy @1GHz vs one ALU array 32 MAC/cy @297MHz) to land in STQ
    part = make_tasks("k", 8 * 256, 1024, 128, [0.001] * 8, [1.0], 256, 128)
    stq, dtq = analyze_kernel(part, VCK5000)
    assert len(stq) == 8 and not dtq
    rep = simulate(stq, dtq, VCK5000)
    # 8 equal tasks over 8 ALU arrays: makespan ≈ one task (or memory bound)
    one = stq[0].t_sparse
    assert rep.makespan <= max(one * 1.01, rep.memory_time)


def test_scheduler_overlaps_queues():
    part = make_tasks("k", 2 * 256, 1024, 128, [0.001, 1.0], [1.0], 256, 128)
    stq, dtq = analyze_kernel(part, VCK5000)
    rep = simulate(stq, dtq, VCK5000)
    serial = sum(t.t_assigned for t in stq + dtq)
    assert rep.makespan <= serial  # PL ∥ AIE overlap


def test_dynamic_beats_forced_baselines():
    """The paper's headline: dynamic mapping ≤ PL-only and ≤ AIE-only."""
    part_args = ("k", 4 * 256, 2048, 128, [0.001, 0.01, 0.5, 1.0], [1.0],
                 256, 128)
    stq, dtq = analyze_kernel(make_tasks(*part_args), VCK5000)
    dyn = simulate(stq, dtq, VCK5000).makespan
    s_stq, s_dtq = force_queue(make_tasks(*part_args), VCK5000, "STQ")
    pl_only = simulate(s_stq, s_dtq, VCK5000).makespan
    d_stq, d_dtq = force_queue(make_tasks(*part_args), VCK5000, "DTQ")
    aie_only = simulate(d_stq, d_dtq, VCK5000).makespan
    assert dyn <= pl_only * 1.0001
    assert dyn <= aie_only * 1.0001


# ------------------------------------------------------------- sparsity
def test_stripe_density_exact():
    x = np.zeros((64, 32), np.float32)
    x[:16] = 1.0
    d = np.asarray(sparsity.stripe_density(jnp.asarray(x), 16, axis=0))
    np.testing.assert_allclose(d, [1.0, 0.0, 0.0, 0.0])
    dc = np.asarray(sparsity.stripe_density(jnp.asarray(x), 8, axis=1))
    np.testing.assert_allclose(dc, [0.25] * 4)


def test_stripe_density_ragged_tail():
    x = np.ones((50, 10), np.float32)
    d = np.asarray(sparsity.stripe_density(jnp.asarray(x), 16, axis=0))
    np.testing.assert_allclose(d, [1.0, 1.0, 1.0, 1.0])


def test_coo_row_stripe_density_matches_dense():
    a = _coo(100, 80, 400, seed=3)
    dense = a.todense()
    want = (dense != 0).reshape(4, 25, 80).sum(axis=(1, 2)) / (25 * 80)
    got = a.row_stripe_density(25)
    np.testing.assert_allclose(got, want, atol=1e-9)


# ------------------------------------------------------------- engine e2e
@pytest.mark.parametrize("mode", ["dynamic", "sparse_only", "dense_only"])
def test_engine_result_mode_invariant(mode):
    a = _coo(128, 128, 300, seed=11)
    h = RNG.normal(size=(128, 24)).astype(np.float32)
    eng = DynasparseEngine(mode=mode, tile_m=32, tile_n=8)
    z, rep = eng.matmul(a, jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(z), a.todense() @ h, rtol=1e-4,
                               atol=1e-4)


def test_engine_literal_equals_fast_path():
    a = _coo(96, 96, 200, seed=13)
    h = (RNG.normal(size=(96, 16)) * (RNG.uniform(size=(96, 16)) < 0.4)
         ).astype(np.float32)
    fast = DynasparseEngine(tile_m=32, tile_n=8)
    lit = DynasparseEngine(tile_m=32, tile_n=8, literal=True)
    z1, _ = fast.matmul(a, jnp.asarray(h))
    z2, _ = lit.matmul(a, jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-4,
                               atol=1e-4)


def test_engine_report_accumulates():
    eng = DynasparseEngine(tile_m=32, tile_n=8)
    h = RNG.normal(size=(64, 16)).astype(np.float32)
    w = RNG.normal(size=(16, 8)).astype(np.float32)
    eng.matmul(jnp.asarray(h), jnp.asarray(w), name="k1")
    eng.matmul(jnp.asarray(h), jnp.asarray(w), name="k2")
    assert len(eng.report.kernels) == 2
    assert eng.report.hardware_time > 0
    tot = eng.report.total
    assert tot.flops_dense_equiv == pytest.approx(2 * 2 * 64 * 16 * 8)


def test_tpu_hw_model_prefers_dense_above_block_density_threshold():
    t_sparse_low = TaskShape(2048, 2048, 2048, alpha_x=0.05, alpha_y=1.0)
    t_sparse_high = TaskShape(2048, 2048, 2048, alpha_x=0.95, alpha_y=1.0)
    assert t_spdmm(t_sparse_low, TPUV5E) < t_dense(t_sparse_low, TPUV5E)
    assert t_spdmm(t_sparse_high, TPUV5E) > t_dense(t_sparse_high, TPUV5E) * 0.9
