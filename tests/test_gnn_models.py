"""GNN model correctness: engine inference == pure-jnp reference."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynasparseEngine
from repro.data.graphs import load_graph, DATASETS
from repro.models import gnn

SMALL_SCALE = 0.02   # shrink datasets for CPU functional runs


@pytest.mark.parametrize("model", gnn.MODELS)
def test_model_matches_reference_small(model):
    g = load_graph("CO", scale=SMALL_SCALE)
    h = g.features_dense
    params = gnn.init_params(model, h.shape[1], 16, g.stats.classes)
    eng = DynasparseEngine(tile_m=32, tile_n=16)
    logits, report = gnn.run_inference(model, eng, g.adj, h, params)
    ref = gnn.run_reference(model, g.adj, h, params)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
    assert report.hardware_time > 0
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("model", gnn.MODELS)
def test_model_literal_execution_small(model):
    """Literal per-queue Pallas execution end-to-end (interpret mode)."""
    g = load_graph("CI", scale=0.01)
    h = g.features_dense
    params = gnn.init_params(model, h.shape[1], 8, g.stats.classes)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    logits, _ = gnn.run_inference(model, eng, g.adj, h, params)
    ref = gnn.run_reference(model, g.adj, h, params)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_dynamic_latency_never_worse_than_baselines():
    g = load_graph("CO", scale=SMALL_SCALE)
    h = g.features_dense
    params = gnn.init_params("GCN", h.shape[1], 16, g.stats.classes)
    times = {}
    for mode in ("dynamic", "sparse_only", "dense_only"):
        eng = DynasparseEngine(mode=mode, tile_m=32, tile_n=16)
        _, report = gnn.run_inference("GCN", eng, g.adj, h, params)
        times[mode] = report.hardware_time
    assert times["dynamic"] <= times["sparse_only"] * 1.0001
    assert times["dynamic"] <= times["dense_only"] * 1.0001


def test_dataset_stats_match_table_iv():
    for name, st in DATASETS.items():
        g = load_graph(name, scale=0.01) if name in ("NE", "RE") else \
            load_graph(name, scale=0.05)
        # density of generated features tracks Table IV
        assert g.feature_density == pytest.approx(st.density_h, rel=0.5, abs=0.002)


def test_full_scale_small_datasets_load():
    g = load_graph("CO")
    assert g.stats.vertices == 2708
    assert g.adj.nnz == 5429 + 2708  # edges + self loops
    assert g.features_dense.shape == (2708, 2708)
    # adjacency density ~ Table IV (0.14%)
    assert g.adj.density == pytest.approx(0.0014, rel=0.5)
