"""Sparse-activation capacity block-skip (tentpole of ISSUE 5).

Compiled whole-model programs used to freeze every activation-side kernel as
a plain dense GEMM; the capacity-padded BlockCSR route packs the activation
ON DEVICE into a fixed stored-block budget so compiled programs skip zero
blocks of intermediate features with fixed shapes.  These tests pin:

- bit-identity of the compiled block-skip route against BOTH eager paths
  (batched host-packed and per-task) across ragged shapes, primitives, eps
  values, dtypes, and capacities (exact / slack);
- the overflow semantics: a batch past the budget takes the dense-GEMM
  fallback INSIDE the same program (bit-identical to the plain dense route),
  never a retrace;
- shape stability: one trace serves any activation sparsity within budget;
- content-independent descriptor caching (act_builds / act_hits);
- the whole-model compiler choosing block-skip vs dense per layer and the
  serving steady state exposing the skip telemetry.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.core import dispatch as dispatch_mod
from repro.core.scheduler import execute_plan
from repro.kernels import ops
from repro.kernels.formats import BlockCSR, pack_blockcsr
from repro.models import gnn


def _block_sparse(rng, m, k, block_density, *, block=8, dtype=np.float32):
    """Dense matrix whose zero pattern is block-structured (the shape of
    post-ReLU feature sparsity the block-skip route exploits)."""
    nrb, ncb = -(-m // block), -(-k // block)
    mask = (rng.uniform(size=(nrb, ncb)) < block_density).astype(np.float32)
    full = rng.normal(size=(nrb * block, ncb * block))
    x = (full * np.kron(mask, np.ones((block, block))))[:m, :k]
    return x.astype(dtype)


def _routes(eng, xd, yd, *, capacity=None, slack=1.5):
    """(plan, act dispatch, compiled z, diag, eager batched z, per-task z)."""
    plan = eng.plan(xd, jnp.asarray(yd))
    ad = eng.activation_dispatch_for(plan, xd, capacity=capacity, slack=slack)
    if ad is None:
        return plan, None, None, None, None, None
    z_a, diag = dispatch_mod.execute_activation(
        ad, xd, yd, interpret=True, stats=eng.cache.stats)
    z_b = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                       batched=True, eps=eng.eps)
    z_p = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                       batched=False, eps=eng.eps)
    return plan, ad, np.asarray(z_a), diag, np.asarray(z_b), np.asarray(z_p)


# ------------------------------------------------------------ kernel level
@pytest.mark.parametrize("tm,tn,mkn,bd,eps,seed", [
    (32, 24, (90, 64, 44), 0.12, 0.0, 1),    # ragged rows, mixed primitives
    (32, 24, (90, 64, 44), 0.12, 0.1, 2),    # eps-thresholded packing
    (16, 8, (40, 32, 20), 0.50, 0.0, 3),     # ragged both axes
    (8, 16, (24, 16, 33), 0.40, 0.0, 4),     # ragged col tail
    (16, 8, (48, 32, 8), 0.05, 0.0, 5),      # nearly empty stripes (fillers)
])
def test_activation_route_bit_identical_to_eager_paths(tm, tn, mkn, bd,
                                                       eps, seed):
    M, K, N = mkn
    rng = np.random.default_rng(seed)
    xd = _block_sparse(rng, M, K, bd)
    yd = (rng.normal(size=(K, N)) *
          (rng.uniform(size=(K, N)) < 0.5)).astype(np.float32)
    eng = DynasparseEngine(tile_m=tm, tile_n=tn, literal=True, eps=eps)
    plan, ad, z_a, diag, z_b, z_p = _routes(eng, xd, yd)
    if ad is None:
        pytest.skip("plan routed no sparse tasks")
    assert not bool(diag["overflow"])
    np.testing.assert_array_equal(z_a, z_b)
    np.testing.assert_array_equal(z_a, z_p)
    if eps == 0.0:
        np.testing.assert_allclose(z_a, xd @ yd, rtol=1e-4, atol=1e-4)


def test_activation_route_skips_blocks():
    """The telemetry must show real skipping on a block-sparse activation:
    stored < logical, and the budget bounds the descriptor count."""
    rng = np.random.default_rng(11)
    xd = _block_sparse(rng, 96, 64, 0.25)
    yd = rng.normal(size=(64, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True)
    _, ad, z_a, diag, z_b, _ = _routes(eng, xd, yd)
    assert ad is not None
    assert int(diag["stored"]) < int(diag["logical"])
    assert int(diag["stored"]) <= int(diag["capacity"])
    np.testing.assert_array_equal(z_a, z_b)


@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_activation_route_dtypes(dtype):
    if dtype == "bfloat16":
        import ml_dtypes
        dtype = ml_dtypes.bfloat16
    rng = np.random.default_rng(13)
    xd = _block_sparse(rng, 64, 32, 0.4, dtype=dtype)
    yd = rng.normal(size=(32, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    _, ad, z_a, _, z_b, z_p = _routes(eng, xd, yd)
    if ad is None:
        pytest.skip("plan routed no sparse tasks")
    np.testing.assert_array_equal(z_a, z_b)
    np.testing.assert_array_equal(z_a, z_p)


def test_capacity_exact_and_overflow_fallback():
    """capacity == exact need is bit-identical to eager; one slot below
    trips the overflow flag and yields the plain dense GEMM result INSIDE
    the same program (no error, no retrace)."""
    rng = np.random.default_rng(17)
    xd = _block_sparse(rng, 64, 48, 0.35)
    yd = rng.normal(size=(48, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    plan = eng.plan(xd, jnp.asarray(yd))
    if not plan.stq:
        pytest.skip("plan routed no sparse tasks")
    need = dispatch_mod.activation_capacity(xd, plan.part, eng.block,
                                            slack=1.0)
    assert need is not None and need > 1

    _, ad, z_a, diag, z_b, _ = _routes(eng, xd, yd, capacity=need)
    assert ad.geom.cap == need and not bool(diag["overflow"])
    np.testing.assert_array_equal(z_a, z_b)

    ad2 = eng.activation_dispatch_for(plan, xd, capacity=need - 1)
    z_o, diag2 = dispatch_mod.execute_activation(ad2, xd, yd, interpret=True)
    assert bool(diag2["overflow"])
    z_d = ops.gemm(jnp.asarray(xd), jnp.asarray(yd), interpret=True,
                   out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(z_o), np.asarray(z_d))
    np.testing.assert_allclose(np.asarray(z_o), xd @ yd,
                               rtol=1e-4, atol=1e-4)


def test_one_trace_serves_varying_sparsity_within_budget():
    """Shape stability: different activation sparsity patterns re-use ONE
    jitted trace (the whole point of the capacity parameterization), and
    the descriptors themselves are cache hits."""
    rng = np.random.default_rng(19)
    yd = rng.normal(size=(48, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    xs = [_block_sparse(rng, 64, 48, bd) for bd in (0.30, 0.18, 0.05)]
    plan = eng.plan(xs[0], jnp.asarray(yd))
    if not plan.stq:
        pytest.skip("plan routed no sparse tasks")
    cap = dispatch_mod.activation_capacity(xs[0], plan.part, eng.block,
                                           slack=1.0)
    s = eng.cache.stats
    t0 = s.trace_builds
    # ONE dispatch — the warmup plan's — serves every later input, exactly
    # as a compiled whole-model program replays its recorded descriptors
    ad = eng.activation_dispatch_for(plan, xs[0], capacity=cap)
    assert ad is not None
    for xd in xs:
        z_a, diag = dispatch_mod.execute_activation(
            ad, xd, yd, interpret=True, stats=s)
        assert not bool(diag["overflow"])
        z_b = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                           batched=True)
        np.testing.assert_array_equal(np.asarray(z_a), np.asarray(z_b))
    assert s.trace_builds == t0 + 1      # ONE trace for all three patterns
    assert s.trace_cache_hits >= 2
    assert s.act_builds == 1


def test_descriptors_content_independent_across_activations():
    """Two different activations with one geometry/assignment must share
    one descriptor lowering (the act cache key has no content in it)."""
    rng = np.random.default_rng(23)
    yd = rng.normal(size=(32, 8)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    x1 = _block_sparse(rng, 48, 32, 0.15)
    # same pattern support, different values -> same densities/assignment
    x2 = (x1 * 1.7).astype(np.float32)
    p1 = eng.plan(x1, jnp.asarray(yd))
    if not p1.stq:
        pytest.skip("plan routed no sparse tasks")
    cap = dispatch_mod.activation_capacity(x1, p1.part, eng.block)
    a1 = eng.activation_dispatch_for(p1, x1, capacity=cap)
    p2 = eng.plan(x2, jnp.asarray(yd))
    a2 = eng.activation_dispatch_for(p2, x2, capacity=cap)
    assert a1 is not None and a1 is a2
    assert eng.cache.stats.act_builds == 1
    assert eng.cache.stats.act_hits == 1
    assert eng.cache.activation_count() == 1


def test_dense_plans_decline_activation_route():
    """A plan whose Analyzer routed everything to the dense engine must NOT
    take the block-skip route — dense wins, the kernel stays one GEMM."""
    rng = np.random.default_rng(29)
    xd = rng.normal(size=(64, 32)).astype(np.float32)      # fully dense
    yd = rng.normal(size=(32, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    plan = eng.plan(xd, jnp.asarray(yd))
    if plan.stq:
        pytest.skip("analyzer unexpectedly routed sparse tasks")
    assert eng.activation_dispatch_for(plan, xd) is None
    # sparse X is dispatch_for's territory, never the activation route's
    adj = SparseCOO((64, 32), jnp.asarray([0]), jnp.asarray([0]),
                    jnp.asarray([1.0]), tag="adjacency")
    plan_adj = eng.plan(adj, jnp.asarray(yd))
    assert eng.activation_dispatch_for(plan_adj, adj) is None


# ------------------------------------------------------------- whole model
def _block_sparse_graph(rng, n=80, nnz=240):
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    return SparseCOO((n, n), jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=nnz)
                                        ).astype(np.float32)),
                     tag="adjacency")


def test_compile_model_uses_activation_route_and_matches():
    """Acceptance (ISSUE 5): a compiled whole-model program executes at
    least one activation-side kernel via the capacity block-skip route,
    matches the reference, and re-serves varying activation sparsity with
    zero retraces and zero overflows."""
    rng = np.random.default_rng(31)
    adj = _block_sparse_graph(rng)
    h = _block_sparse(rng, 80, 12, 0.35)
    params = gnn.init_params("GCN", 12, 8, 5)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    warm, cm = gnn.compile_model("GCN", eng, adj, jnp.asarray(h), params)
    assert cm is not None
    assert cm.n_act >= 1, "no activation kernel took the block-skip route"
    ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(warm), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)

    z1 = cm(jnp.asarray(h))
    assert len(cm.last_activation) == cm.n_act
    assert all(not bool(d["overflow"]) for d in cm.last_activation)
    assert any(int(d["stored"]) < int(d["logical"])
               for d in cm.last_activation)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)

    # sparser variant of the same support: same trace, still exact
    h2 = (h * (rng.uniform(size=h.shape) < 0.7)).astype(np.float32)
    z2 = cm(jnp.asarray(h2))
    assert cm.calls == 2 and cm.traces == 1
    ref2 = gnn.run_reference("GCN", adj, jnp.asarray(h2), params)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(ref2),
                               rtol=1e-3, atol=1e-3)


def test_compile_model_activation_skip_off_keeps_dense_route():
    rng = np.random.default_rng(37)
    adj = _block_sparse_graph(rng)
    h = _block_sparse(rng, 80, 12, 0.35)
    params = gnn.init_params("GCN", 12, 8, 5)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    warm, cm = gnn.compile_model("GCN", eng, adj, jnp.asarray(h), params,
                                 activation_skip=False)
    assert cm is not None and cm.n_act == 0
    z = cm(jnp.asarray(h))
    assert cm.last_activation == []
    ref = gnn.run_reference("GCN", adj, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_serving_steady_state_reports_skip_telemetry():
    """Post-warmup micro-batches must run compiled WITH the block-skip
    route active (skipped ratio > 0, zero overflows, zero replans) while
    activation sparsity varies within the capacity budget."""
    from repro.serving import ServingConfig, ServingEngine, SharedPlanCache

    rng = np.random.default_rng(41)
    adj = _block_sparse_graph(rng)
    params = gnn.init_params("GCN", 12, 8, 5)
    base = _block_sparse(rng, 80, 12, 0.35)
    batches = []
    for _ in range(12):
        jitter = (rng.uniform(size=base.shape) < 0.95)
        batches.append((base * jitter).astype(np.float32))

    cache = SharedPlanCache()
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    with ServingEngine("GCN", params, engine=eng,
                       config=ServingConfig(max_batch=4)) as srv:
        srv.register_graph("g", adj)
        outs = srv.serve(("g", h) for h in batches)
    ref = gnn.run_reference("GCN", adj, jnp.asarray(batches[0]), params)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
    ds = srv.dispatch_stats()
    assert srv.stats.compiled_batches == srv.stats.batches - 1
    assert ds["replans"] == 0
    assert ds["act_kernels_last"] >= 1
    # steady-state compiled batches replay the cached activation
    # dispatches — the hit counter must reflect that reuse
    assert ds["act_hits"] > 0
    assert ds["act_overflows"] == 0
    assert ds["act_skipped_ratio_mean"] > 0.0
    assert len(srv.stats.activation_batches) == srv.stats.compiled_batches


# --------------------------------------------------- eager pack regression
def _pack_blockcsr_loop(x, block, *, capacity=None, eps=0.0):
    """The pre-ISSUE-5 per-block double loop — kept as the reference the
    vectorized ``pack_blockcsr`` must reproduce bit-for-bit."""
    x = np.asarray(x)
    M, K = x.shape
    B = block
    nrb, ncb = -(-M // B), -(-K // B)
    padded = np.zeros((nrb * B, ncb * B), dtype=x.dtype)
    padded[:M, :K] = x

    def _stored(blk):
        return np.any(blk != 0) if eps == 0.0 else np.any(np.abs(blk) > eps)

    rows, cols, first, blocks = [], [], [], []
    for rb in range(nrb):
        row_has = False
        for cb in range(ncb):
            blk = padded[rb * B:(rb + 1) * B, cb * B:(cb + 1) * B]
            if _stored(blk):
                rows.append(rb)
                cols.append(cb)
                first.append(0 if row_has else 1)
                blocks.append(blk)
                row_has = True
        if not row_has:
            rows.append(rb)
            cols.append(0)
            first.append(1)
            blocks.append(np.zeros((B, B), dtype=x.dtype))
    nnzb = len(blocks)
    cap = capacity if capacity is not None else nnzb
    for _ in range(cap - nnzb):
        rows.append(nrb - 1)
        cols.append(0)
        first.append(0)
        blocks.append(np.zeros((B, B), dtype=x.dtype))
    return BlockCSR((M, K), B, jnp.asarray(rows, dtype=jnp.int32),
                    jnp.asarray(cols, dtype=jnp.int32),
                    jnp.asarray(first, dtype=jnp.int32),
                    jnp.asarray(np.stack(blocks)), nnzb)


# ------------------------------------------- per-stripe capacity budgets
def _skewed_activation(rng, m=96, k=64, block=8):
    """One dense row-stripe, the rest nearly empty — the skew case where a
    uniform budget pads every stripe to the dense stripe's need."""
    x = np.zeros((m, k), np.float32)
    x[:16] = rng.normal(size=(16, k)).astype(np.float32)
    tail = _block_sparse(rng, m - 16, k, 0.06, block=block)
    x[16:] = tail
    return x


def test_pack_vector_capacity_uniform_is_bit_identical():
    """A per-stripe vector with every entry equal to the scalar budget must
    reproduce the historical uniform layout bit-for-bit."""
    rng = np.random.default_rng(51)
    x = _block_sparse(rng, 64, 32, 0.3)
    kw = dict(block=8, n_stripes=4, slot_rows=2, n_block_cols=4, eps=0.0)
    out_s = ops.pack_activation_stripes(x, capacity=5, **kw)
    out_v = ops.pack_activation_stripes(
        x, capacity=np.full(4, 5, np.int64), **kw)
    for a, b in zip(out_s, out_v):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_vector_capacity_trims_and_detects_overflow():
    rng = np.random.default_rng(53)
    x = _skewed_activation(rng)
    kw = dict(block=8, n_stripes=6, slot_rows=2, n_block_cols=8, eps=0.0)
    *_, nnzb, _real, ovf = ops.pack_activation_stripes(x, capacity=16, **kw)
    needs = np.asarray(nnzb)
    assert not bool(ovf)
    # exact per-stripe budgets: packed pool shrinks to sum(needs), no loss
    out = ops.pack_activation_stripes(x, capacity=needs, **kw)
    assert out[0].shape[0] == int(needs.sum()) < 6 * 16
    assert not bool(out[-1])
    # starving ONE stripe below its need must raise the overflow flag
    starved = needs.copy()
    starved[0] -= 1
    assert bool(ops.pack_activation_stripes(x, capacity=starved, **kw)[-1])


def test_per_stripe_budgets_cut_waste_bit_identically():
    """Acceptance (ISSUE 7 leg 2): on a skewed activation the per-stripe
    budget vector drops padded-slot waste ≥20% vs the uniform budget, with
    zero overflows and the identical (bitwise) compiled result."""
    rng = np.random.default_rng(57)
    xd = _skewed_activation(rng)
    yd = rng.normal(size=(64, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    plan = eng.plan(xd, jnp.asarray(yd))
    ad_u = eng.activation_dispatch_for(plan, xd, per_stripe=False)
    ad_v = eng.activation_dispatch_for(plan, xd, per_stripe=True)
    if ad_u is None:
        pytest.skip("plan routed no sparse tasks")
    assert ad_u.geom.caps == () and ad_v.geom.caps != ()
    assert ad_v.geom.total_slots < ad_u.geom.total_slots

    z_u, diag_u = dispatch_mod.execute_activation(ad_u, xd, yd,
                                                  interpret=True)
    z_v, diag_v = dispatch_mod.execute_activation(ad_v, xd, yd,
                                                  interpret=True)
    assert not bool(diag_u["overflow"]) and not bool(diag_v["overflow"])
    np.testing.assert_array_equal(np.asarray(z_u), np.asarray(z_v))
    z_b = execute_plan(plan.part, plan.stq, plan.dtq, xd, yd,
                       batched=True, eps=eng.eps)
    np.testing.assert_array_equal(np.asarray(z_v), np.asarray(z_b))

    stored = int(diag_v["stored"])
    waste_u = (int(diag_u["capacity"]) - stored) / max(stored, 1)
    waste_v = (int(diag_v["capacity"]) - stored) / max(stored, 1)
    assert waste_v <= 0.8 * waste_u, (waste_u, waste_v)


def test_per_stripe_budget_serves_jitter_without_overflow():
    """Jitter only removes elements from the warmup support, so each
    stripe's need can only shrink: the warmup-sized budget vector serves
    every jittered batch with zero overflows (and one shared descriptor
    build)."""
    rng = np.random.default_rng(59)
    xd = _skewed_activation(rng)
    yd = rng.normal(size=(64, 16)).astype(np.float32)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    plan = eng.plan(xd, jnp.asarray(yd))
    ad = eng.activation_dispatch_for(plan, xd, per_stripe=True)
    if ad is None:
        pytest.skip("plan routed no sparse tasks")
    builds0 = eng.cache.stats.act_builds
    for i in range(4):
        xi = (xd * (rng.uniform(size=xd.shape) < 0.9)).astype(np.float32)
        z, diag = dispatch_mod.execute_activation(ad, xi, yd, interpret=True)
        assert not bool(diag["overflow"]), i
        z_b = execute_plan(plan.part, plan.stq, plan.dtq, xi, yd,
                           batched=True, eps=eng.eps)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(z_b))
        # same dispatch replayed — no rebuilds per batch
        assert eng.cache.stats.act_builds == builds0


# ------------------------------------------- steady-state act_hits credit
def test_compiled_model_credits_act_hits():
    """Regression (ISSUE 7 satellite): compiled steady-state calls replay
    the cached activation dispatches, so ``act_hits`` must grow past
    warmup — BENCH_dispatch.json used to read ``act_builds: 2, act_hits:
    0`` across 6 batches while every batch reused them."""
    rng = np.random.default_rng(61)
    adj = _block_sparse_graph(rng)
    h = _block_sparse(rng, 80, 12, 0.35)
    params = gnn.init_params("GCN", 12, 8, 5)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True)
    _, cm = gnn.compile_model("GCN", eng, adj, jnp.asarray(h), params)
    assert cm is not None and cm.n_act >= 1
    hits0 = eng.cache.stats.act_hits
    cm(jnp.asarray(h))
    cm(jnp.asarray(h))
    assert eng.cache.stats.act_hits == hits0 + 2 * cm.n_act
    assert eng.cache.stats.act_hits > 0


@pytest.mark.parametrize("seed", range(6))
def test_vectorized_pack_blockcsr_matches_loop(seed):
    rng = np.random.default_rng(seed)
    M, K = int(rng.integers(1, 45)), int(rng.integers(1, 45))
    B = int(rng.choice([4, 8]))
    eps = float(rng.choice([0.0, 0.1]))
    x = (rng.normal(size=(M, K)) *
         (rng.uniform(size=(M, K)) < rng.uniform(0, 0.6))).astype(np.float32)
    ref = _pack_blockcsr_loop(x, B, eps=eps)
    cap = ref.nnzb + int(rng.integers(0, 4))
    ref = _pack_blockcsr_loop(x, B, capacity=cap, eps=eps)
    got = pack_blockcsr(x, B, capacity=cap, eps=eps)
    assert got.nnzb == ref.nnzb
    for f in ("row_ids", "col_ids", "first", "blocks"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(ref, f)))
