"""SharedPlanCache: byte-accounted LRU eviction, multi-graph keying,
persistence round-trips, and the lazy-densify structure entries."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DynasparseEngine, SparseCOO
from repro.core.plancache import PlanCache, nbytes_of
from repro.models import gnn
from repro.serving import (GraphKey, SharedPlanCache, get_shared_cache,
                           set_shared_cache)

RNG = np.random.default_rng(31)


def _rand_graph(n=64, nnz=180, seed=5):
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=nnz)
                                        ).astype(np.float32)),
                     tag="adjacency")


# ------------------------------------------------------------ byte account
def test_nbytes_counts_array_payload():
    assert nbytes_of(np.zeros((4, 4), np.float32)) == 64
    assert nbytes_of({"a": np.zeros(2, np.float64), "b": [1, 2]}) >= 32
    assert nbytes_of(None) > 0


def test_bytes_used_tracks_puts_and_eviction_by_bytes():
    c = PlanCache(capacity=1000, max_bytes=1000)
    c._put("density", ("a",), np.zeros(100, np.float64))   # 800 B
    assert c.bytes_used == 800
    c._put("density", ("b",), np.zeros(100, np.float64))   # over budget
    assert c.stats.evictions == 1
    assert c.bytes_used == 800                             # 'a' evicted
    assert c._get("density", ("a",)) is None
    assert c._get("density", ("b",)) is not None
    assert c.stats.bytes_evicted == 800


def test_lru_order_spans_entry_kinds():
    c = PlanCache(capacity=1000, max_bytes=2000)
    c._put("density", ("cold",), np.zeros(100, np.float64))
    c._put("plan", ("hot",), np.zeros(100, np.float64))
    c._get("density", ("cold",))        # touch: 'cold' is now most recent
    c._put("struct", ("new",), np.zeros(100, np.float64))  # evicts 'hot'
    assert c._get("plan", ("hot",)) is None
    assert c._get("density", ("cold",)) is not None


def test_engine_respects_byte_budget_across_graphs():
    """Many distinct graphs through a tiny byte budget: the cache must stay
    under budget and keep serving correct results."""
    cache = SharedPlanCache(capacity=10_000, max_bytes=64 * 1024)
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    h = RNG.normal(size=(64, 8)).astype(np.float32)
    for seed in range(6):
        adj = _rand_graph(seed=100 + seed)
        z, _ = eng.matmul(adj, jnp.asarray(h), name=f"g{seed}")
        np.testing.assert_allclose(np.asarray(z), adj.todense() @ h,
                                   rtol=1e-4, atol=1e-4)
    assert cache.bytes_used <= 64 * 1024
    assert cache.stats.evictions > 0


# ------------------------------------------------------------- multi-graph
def test_graph_registry_keys_on_content():
    cache = SharedPlanCache()
    a, b = _rand_graph(seed=1), _rand_graph(seed=2)
    ka = cache.register_graph("a", a)
    kb = cache.register_graph("b", b)
    assert isinstance(ka, GraphKey) and ka != kb
    assert ka.shape == (64, 64) and ka.dtype == "float32"
    assert cache.register_graph("a2", a) == ka      # same content, same key
    # re-registering an id with new content updates the registry
    assert cache.register_graph("a", b) == kb
    assert cache.graphs["a"] == kb


def test_two_engines_share_one_packing():
    cache = SharedPlanCache()
    adj = _rand_graph(seed=3)
    h = RNG.normal(size=(64, 8)).astype(np.float32)
    e1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    e2 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    e1.matmul(adj, jnp.asarray(h))
    e2.matmul(adj, jnp.asarray(h))
    assert cache.stats.packs == 1                   # second engine: all hits
    assert cache.stats.analyzes == 1
    assert cache.stats.plan_hits == 1


def test_shared_singleton_roundtrip():
    try:
        set_shared_cache(None)
        c = get_shared_cache()
        assert get_shared_cache() is c
        mine = SharedPlanCache()
        set_shared_cache(mine)
        assert get_shared_cache() is mine
    finally:
        set_shared_cache(None)


# ------------------------------------------------------------- persistence
def test_save_load_skips_reanalysis(tmp_path):
    adj = _rand_graph(seed=7)
    params = gnn.init_params("GCN", 12, 8, 5)
    h = RNG.normal(size=(64, 12)).astype(np.float32)

    c1 = SharedPlanCache()
    e1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=c1)
    z1, _ = gnn.run_inference("GCN", e1, adj, jnp.asarray(h), params)
    path = os.fspath(tmp_path / "plans.pkl")
    manifest = c1.save(path)
    assert manifest["entries"] == len(c1) and manifest["bytes"] > 0

    c2 = SharedPlanCache()
    assert c2.load(path)["entries"] == manifest["entries"]
    e2 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=c2)
    z2, _ = gnn.run_inference("GCN", e2, adj, jnp.asarray(h), params)
    # restart: zero re-analysis, zero re-packing, identical results
    assert c2.stats.packs == 0 and c2.stats.analyzes == 0
    assert c2.stats.plan_misses == 0
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_save_load_restores_compiled_dispatch(tmp_path):
    """A restart must also replay zero descriptor lowering: the compiled
    dispatch entries round-trip (arrays re-uploaded to device) and the
    restored engine serves from them bit-identically."""
    adj = _rand_graph(seed=9)
    params = gnn.init_params("GCN", 12, 8, 5)
    h = RNG.normal(size=(64, 12)).astype(np.float32)

    c1 = SharedPlanCache()
    e1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=c1)
    z1, _ = gnn.run_inference("GCN", e1, adj, jnp.asarray(h), params)
    assert c1.stats.dispatch_builds >= 1
    assert c1.dispatch_count() == c1.stats.dispatch_builds
    path = os.fspath(tmp_path / "dispatch.pkl")
    c1.save(path)

    c2 = SharedPlanCache()
    c2.load(path)
    assert c2.dispatch_count() == c1.dispatch_count()
    import jax
    for (kind, _k), v in c2.items():
        if kind == SharedPlanCache._DISPATCH:
            assert all(isinstance(a, jax.Array) for a in v.arrays.values())
    e2 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=c2)
    z2, _ = gnn.run_inference("GCN", e2, adj, jnp.asarray(h), params)
    assert c2.stats.dispatch_builds == 0        # served from the snapshot
    assert c2.stats.dispatch_hits >= 1
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_load_restores_device_resident_structures(tmp_path):
    """Restored packed stripes must be device arrays — the hot path may not
    pay a host->device upload per micro-batch after a restart."""
    import jax
    adj = _rand_graph(seed=8)
    c1 = SharedPlanCache()
    e1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=c1)
    e1.matmul(adj, jnp.asarray(RNG.normal(size=(64, 8)).astype(np.float32)))
    path = os.fspath(tmp_path / "p.pkl")
    c1.save(path)
    c2 = SharedPlanCache()
    c2.load(path)
    structs = [v for (kind, _), v in c2.items() if kind == "struct"]
    assert structs, "no structure entries restored"
    for s in structs:
        for bcsr in s.stripes.values():
            assert isinstance(bcsr.blocks, jax.Array)
            assert isinstance(bcsr.row_ids, jax.Array)


def test_reregister_purges_superseded_content(tmp_path):
    """Regression (ISSUE 5): re-registering a graph_id with different
    adjacency content used to leave the old content's entries in the cache;
    a save()/load() round-trip then resurrected the stale CompiledDispatch
    (old descriptors + block payloads) under the superseded key, growing
    the snapshot by one dead graph per swap and squatting in the byte
    budget.  Re-registration must purge them — unless another id still
    maps to the same content."""
    from repro.core.plancache import key_mentions

    adjA, adjB = _rand_graph(seed=21), _rand_graph(seed=22)
    params = gnn.init_params("GCN", 12, 8, 5)
    h = RNG.normal(size=(64, 12)).astype(np.float32)

    cache = SharedPlanCache()
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=cache)
    gnn.run_inference("GCN", eng, adjA, jnp.asarray(h), params)
    fpA = GraphKey.of(adjA).fingerprint
    cache.register_graph("g", adjA)
    nA = sum(1 for (k, key), _ in cache.items() if key_mentions(key, fpA))
    assert nA > 0 and cache.dispatch_count() >= 1

    # second id on the same content protects it ...
    cache.register_graph("g2", adjA)
    cache.register_graph("g", adjB)
    assert sum(1 for (k, key), _ in cache.items()
               if key_mentions(key, fpA)) == nA
    # ... dropping the last reference purges every level of the old content
    cache.register_graph("g2", adjB)
    assert sum(1 for (k, key), _ in cache.items()
               if key_mentions(key, fpA)) == 0
    assert cache.stats.invalidations == nA

    # and a save after the swap can no longer resurrect it cross-restart
    path = os.fspath(tmp_path / "swap.pkl")
    cache.save(path)
    c2 = SharedPlanCache()
    c2.load(path)
    assert not any(key_mentions(key, fpA) for (k, key), _ in c2.items())


def test_load_skips_entries_of_superseded_registration(tmp_path):
    """Cross-restart regression (ISSUE 5): a restarted process that
    registers the changed graph BEFORE loading the old snapshot must not
    resurrect the superseded content's entries, and the live registry
    mapping must win over the snapshot's."""
    from repro.core.plancache import key_mentions

    adjA, adjB = _rand_graph(seed=23), _rand_graph(seed=24)
    params = gnn.init_params("GCN", 12, 8, 5)
    h = RNG.normal(size=(64, 12)).astype(np.float32)

    c1 = SharedPlanCache()
    e1 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=c1)
    gnn.run_inference("GCN", e1, adjA, jnp.asarray(h), params)
    c1.register_graph("g", adjA)
    path = os.fspath(tmp_path / "restart.pkl")
    c1.save(path)
    fpA = GraphKey.of(adjA).fingerprint
    nA = sum(1 for (k, key), _ in c1.items() if key_mentions(key, fpA))

    # "restart": the graph under id g changed to B before the load
    c2 = SharedPlanCache()
    c2.register_graph("g", adjB)
    manifest = c2.load(path)
    assert manifest["stale_skipped"] == nA
    assert not any(key_mentions(key, fpA) for (k, key), _ in c2.items())
    assert c2.graphs["g"] == GraphKey.of(adjB)      # live mapping wins
    # serving B through the restored cache stays correct
    e2 = DynasparseEngine(tile_m=16, tile_n=8, literal=True, cache=c2)
    z, _ = gnn.run_inference("GCN", e2, adjB, jnp.asarray(h), params)
    ref = gnn.run_reference("GCN", adjB, jnp.asarray(h), params)
    np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_load_rejects_unknown_version(tmp_path):
    """A wrong-version snapshot must produce a cold start — counted and
    carrying the explicit version message — never an unhandled raise (a
    stale snapshot format may not take serving down)."""
    import pickle
    path = os.fspath(tmp_path / "bad.pkl")
    with open(path, "wb") as f:
        pickle.dump({"version": 999, "entries": [], "graphs": {}}, f)
    cache = SharedPlanCache()
    manifest = cache.load(path)
    assert manifest["cold_start"] is True
    assert manifest["entries"] == 0
    assert "snapshot version" in manifest["error"]
    assert cache.stats.snapshot_errors == 1
    assert len(cache) == 0


def test_load_skips_sharded_dispatch_from_bigger_mesh(tmp_path):
    """A snapshot carrying an 8-device sharded dispatch must not poison a
    1-device restart: the oversized entry is skipped (and counted in the
    manifest), while a mesh-1 sharded entry loads and is re-uploaded."""
    import pickle

    from repro.core.dispatch import DispatchGeometry
    from repro.core.shard_exec import ShardedDispatch
    from repro.serving.cache import _PERSIST_VERSION

    geom = DispatchGeometry(M=16, K=16, N=8, tm=8, tn=8, SM=8, SN=8, B=8,
                            nrt=2, nct=1, has_gemm=False, has_spdmm=True,
                            has_spmm=False)
    arrays = {"sp_a": np.zeros((1, 3), np.int32)}

    def shard(nd):
        return ShardedDispatch(
            geom=geom, n_devices=nd, band_starts=tuple(range(nd + 1)),
            band_rows=(16,) * nd, M=16, arrays=dict(arrays),
            fingerprint=f"fp{nd}")

    path = os.fspath(tmp_path / "mesh.pkl")
    entries = [(("sharddispatch", ("k8", "fp8", 8)), shard(8)),
               (("sharddispatch", ("k1", "fp1", 1)), shard(1))]
    with open(path, "wb") as f:
        pickle.dump({"version": _PERSIST_VERSION, "entries": entries,
                     "graphs": {}}, f)

    cache = SharedPlanCache()
    manifest = cache.load(path)
    assert manifest["mesh_skipped"] == 1
    assert manifest["entries"] == 1
    kept = {key for (kind, key), _ in cache.items()
            if kind == "sharddispatch"}
    assert kept == {("k1", "fp1", 1)}
    # the survivor's descriptor arrays were re-uploaded to the device
    (value,) = [v for (kind, _), v in cache.items()
                if kind == "sharddispatch"]
    import jax
    assert isinstance(value.arrays["sp_a"], jax.Array)


# ----------------------------------------------------------- lazy densify
def test_structure_entry_densifies_only_for_dense_queue():
    """An all-sparse plan must never materialize the dense adjacency; the
    byte account must grow when a dense-queue plan forces it."""
    adj = _rand_graph(seed=9)                        # very sparse: all-STQ
    cache = SharedPlanCache()
    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                           mode="sparse_only", cache=cache)
    h = RNG.normal(size=(64, 8)).astype(np.float32)
    eng.matmul(adj, jnp.asarray(h))
    entries = {k: v for k, v in cache.items()}
    structs = [v for (kind, _), v in entries.items() if kind == "struct"]
    assert len(structs) == 1 and structs[0].dense is None

    bytes_before = cache.bytes_used
    eng_d = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                             mode="dense_only", cache=cache)
    z, _ = eng_d.matmul(adj, jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(z), adj.todense() @ h,
                               rtol=1e-4, atol=1e-4)
    assert structs[0].dense is not None              # materialized on demand
    assert cache.bytes_used > bytes_before           # and re-accounted
