"""Per-kernel allclose validation against the pure-jnp oracles.

All Pallas kernels run in interpret mode on CPU (TPU is the target).
Shapes/dtypes are swept deterministically here; the hypothesis-driven
property sweeps live in ``test_properties.py`` (guarded import — the suite
must collect without the optional dev dependency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.formats import pack_blockcsr, pack_blockcsr_coo

jax.config.update("jax_enable_x64", False)

RNG = np.random.default_rng(1234)


def _rand(m, n, dtype, density=1.0, block_mask=None, block=None):
    x = RNG.normal(size=(m, n)).astype(np.float32)
    if density < 1.0 and block_mask is None:
        mask = RNG.uniform(size=(m, n)) < density
        x = x * mask
    if block_mask is not None:
        bm = np.kron(block_mask, np.ones((block, block)))[:m, :n]
        x = x * bm
    return x.astype(dtype)


TOL = {np.float32: 2e-5, jnp.bfloat16: 2e-1}


# ---------------------------------------------------------------- GEMM
@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (32, 16, 24), (128, 128, 128),
                                   (100, 60, 36), (256, 128, 64)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gemm_matches_ref(m, k, n, dtype):
    x = _rand(m, k, dtype)
    y = _rand(k, n, dtype)
    got = ops.gemm(jnp.asarray(x), jnp.asarray(y), bm=32, bn=32, bk=32,
                   interpret=True, out_dtype=jnp.float32)
    want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(y), out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=TOL[dtype], atol=TOL[dtype] * 10)


def test_gemm_block_shape_sweep():
    x = _rand(64, 48, np.float32)
    y = _rand(48, 80, np.float32)
    want = np.asarray(ref.gemm_ref(jnp.asarray(x), jnp.asarray(y)))
    for b in (8, 16, 64, 128):
        got = ops.gemm(jnp.asarray(x), jnp.asarray(y), bm=b, bn=b, bk=b,
                       interpret=True)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------- SpDMM
@pytest.mark.parametrize("block", [8, 16])
@pytest.mark.parametrize("density", [0.0, 0.1, 0.5, 1.0])
def test_spdmm_block_density_sweep(block, density):
    m, k, n = 4 * block, 6 * block, 3 * block
    nrb, ncb = m // block, k // block
    block_mask = (RNG.uniform(size=(nrb, ncb)) < density).astype(np.float32)
    a_dense = _rand(m, k, np.float32, block_mask=block_mask, block=block)
    y = _rand(k, n, np.float32)
    a = pack_blockcsr(a_dense, block)
    got = ops.spdmm(a, jnp.asarray(y), bn=block, interpret=True)
    want = a_dense.astype(np.float32) @ y
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-4)


def test_spdmm_ragged_shapes():
    # logical shapes not multiples of block
    block = 16
    a_dense = _rand(50, 70, np.float32, density=0.2)
    y = _rand(70, 36, np.float32)
    a = pack_blockcsr(a_dense, block)
    got = ops.spdmm(a, jnp.asarray(y), bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a_dense @ y, rtol=2e-5,
                               atol=2e-4)


def test_spdmm_capacity_padding_is_noop():
    block = 8
    a_dense = _rand(32, 32, np.float32, density=0.3)
    y = _rand(32, 16, np.float32)
    a0 = pack_blockcsr(a_dense, block)
    a1 = pack_blockcsr(a_dense, block, capacity=a0.stored_blocks + 7)
    g0 = ops.spdmm(a0, jnp.asarray(y), bn=8, interpret=True)
    g1 = ops.spdmm(a1, jnp.asarray(y), bn=8, interpret=True)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spdmm_dtypes(dtype):
    block = 8
    a_dense = _rand(24, 40, dtype, density=0.4)
    y = _rand(40, 24, dtype)
    a = pack_blockcsr(a_dense, block)
    got = ops.spdmm(a, jnp.asarray(y), bn=8, interpret=True)
    want = np.asarray(a_dense, np.float32) @ np.asarray(y, np.float32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=TOL[dtype],
                               atol=TOL[dtype] * 10)


# ---------------------------------------------------------------- SpMM
@pytest.mark.parametrize("da,dy", [(0.0, 0.5), (0.2, 0.2), (0.5, 1.0),
                                   (1.0, 1.0), (1.0, 0.0)])
def test_spmm_density_sweep(da, dy):
    block = 8
    m, k, n = 3 * block, 4 * block, 2 * block
    am = (RNG.uniform(size=(m // block, k // block)) < da).astype(np.float32)
    ym = (RNG.uniform(size=(k // block, n // block)) < dy).astype(np.float32)
    a_dense = _rand(m, k, np.float32, block_mask=am, block=block)
    y_dense = _rand(k, n, np.float32, block_mask=ym, block=block)
    a = pack_blockcsr(a_dense, block)
    y = pack_blockcsr(y_dense, block)
    got = ops.spmm(a, y, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a_dense @ y_dense,
                               rtol=2e-5, atol=2e-4)


def test_spmm_ragged():
    block = 8
    a_dense = _rand(20, 28, np.float32, density=0.3)
    y_dense = _rand(28, 12, np.float32, density=0.3)
    a = pack_blockcsr(a_dense, block)
    y = pack_blockcsr(y_dense, block)
    got = ops.spmm(a, y, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a_dense @ y_dense,
                               rtol=2e-5, atol=2e-4)


def test_blockcsr_roundtrip():
    a_dense = _rand(40, 24, np.float32, density=0.25)
    a = pack_blockcsr(a_dense, 8)
    np.testing.assert_allclose(np.asarray(a.todense()), a_dense, atol=0)


# ------------------------------------------------- COO packing (no densify)
def _assert_blockcsr_identical(a, b):
    assert a.shape == b.shape and a.block_size == b.block_size
    assert a.nnzb == b.nnzb
    np.testing.assert_array_equal(np.asarray(a.row_ids), np.asarray(b.row_ids))
    np.testing.assert_array_equal(np.asarray(a.col_ids), np.asarray(b.col_ids))
    np.testing.assert_array_equal(np.asarray(a.first), np.asarray(b.first))
    # bit-identical blocks, not allclose: COO packing must sum duplicates in
    # triplet order exactly like np.add.at on the densified matrix
    np.testing.assert_array_equal(np.asarray(a.blocks), np.asarray(b.blocks))


@pytest.mark.parametrize("m,k,eps", [(40, 24, 0.0), (37, 21, 0.0),
                                     (64, 64, 1e-6)])
def test_pack_blockcsr_coo_bit_identical_to_dense_path(m, k, eps):
    dense = _rand(m, k, np.float32, density=0.15)
    if eps > 0:   # sprinkle sub-eps values that must not resurrect a block
        dense[dense == 0] = np.where(
            RNG.uniform(size=(dense == 0).sum()) < 0.2, 1e-9, 0.0
        ).astype(np.float32)
    r, c = np.nonzero(dense)
    got = pack_blockcsr_coo((m, k), r.astype(np.int32), c.astype(np.int32),
                            dense[r, c], 8, eps=eps)
    want = pack_blockcsr(dense, 8, eps=eps)
    _assert_blockcsr_identical(got, want)


def test_pack_blockcsr_coo_duplicates_sum_in_order():
    # duplicate coordinates: the dense oracle accumulates with np.add.at in
    # triplet order; the COO pack must produce the same float32 bit pattern
    rows = np.array([0, 0, 5, 0, 5], dtype=np.int32)
    cols = np.array([1, 1, 3, 1, 3], dtype=np.int32)
    vals = np.array([0.1, 0.7, -0.3, 1e-8, 0.30000001], dtype=np.float32)
    dense = np.zeros((8, 8), np.float32)
    np.add.at(dense, (rows, cols), vals)
    got = pack_blockcsr_coo((8, 8), rows, cols, vals, 4)
    want = pack_blockcsr(dense, 4)
    _assert_blockcsr_identical(got, want)


def test_pack_blockcsr_coo_rejects_out_of_bounds():
    for bad_r, bad_c in [(-1, 0), (16, 0), (0, -2), (0, 8)]:
        with pytest.raises(ValueError, match="out of bounds"):
            pack_blockcsr_coo((16, 8), np.array([bad_r], np.int32),
                              np.array([bad_c], np.int32),
                              np.ones(1, np.float32), 8)


def test_pack_blockcsr_coo_empty_and_capacity():
    got = pack_blockcsr_coo((16, 8), np.zeros(0, np.int32),
                            np.zeros(0, np.int32), np.zeros(0, np.float32),
                            8, capacity=4)
    want = pack_blockcsr(np.zeros((16, 8), np.float32), 8, capacity=4)
    _assert_blockcsr_identical(got, want)
    assert got.stored_blocks == 4 and got.nnzb == 2  # one zero block per row
