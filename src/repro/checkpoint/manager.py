"""Fault-tolerant checkpointing: async, atomic, sharded, reshardable.

Layout per step::

    <dir>/step_000123.tmp/      (written)
    <dir>/step_000123/          (atomic rename on completion)
        manifest.json           step, config hash, pytree structure, shapes
        arr_<idx>.npy           one file per leaf (host-gathered)

Design points for 1000+ node deployments (documented vs. implemented here):
- *Atomicity*: rename-on-complete; a crashed writer leaves only ``.tmp``
  which restore ignores and the next save garbage-collects.
- *Async*: ``save`` snapshots to host memory (device_get) and hands the file
  I/O to a background thread — the train loop resumes immediately; ``wait``
  joins before the next save (single outstanding snapshot).
- *Resharding*: restore places each leaf with the CALLER's shardings, so a
  checkpoint written on a 2x16x16 mesh restores onto 16x16 (elastic
  downsizing) or any other mesh — leaves are stored unsharded (gathered).
  At real scale this becomes per-shard files + distributed gather; the
  manifest format already records per-leaf shape/dtype to support it.
- *Retention*: keep the last ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _tree_paths(tree: Any) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat]


def config_hash(cfg: Any) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 cfg: Any = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.cfg_hash = config_hash(cfg) if cfg is not None else None
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        self.wait()  # one outstanding snapshot
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        # snapshot to host BEFORE returning control (consistent cut)
        host = [(jax.tree_util.keystr(p), np.asarray(jax.device_get(x)))
                for p, x in flat]
        manifest = {
            "step": step,
            "time": time.time(),
            "config_hash": self.cfg_hash,
            "leaves": [{"path": p, "shape": list(a.shape),
                        "dtype": str(a.dtype)} for p, a in host],
        }

        def write():
            tmp = self.dir / f"step_{step:09d}.tmp"
            final = self.dir / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, (_, a) in enumerate(host):
                np.save(tmp / f"arr_{i}.npy", a)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
        for tmp in self.dir.glob("*.tmp"):
            shutil.rmtree(tmp, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_template: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore into the template's structure.  ``shardings`` (optional
        pytree of NamedShardings) places leaves directly on the CURRENT mesh
        — this is the elastic-resharding path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if (self.cfg_hash and manifest["config_hash"]
                and manifest["config_hash"] != self.cfg_hash):
            raise ValueError(
                f"checkpoint config hash {manifest['config_hash']} != "
                f"current {self.cfg_hash}")
        want_paths = _tree_paths(state_template)
        have = {l["path"]: i for i, l in enumerate(manifest["leaves"])}
        missing = [p for p in want_paths if p not in have]
        if missing:
            raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")

        leaves = []
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(state_template)
        if shardings is not None:
            # shardings may be a PREFIX tree (None standing for subtrees):
            # broadcast each prefix leaf over its matching template subtree
            flat_s: list = []
            prefix_flat, _ = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: (x is None or isinstance(
                    x, jax.sharding.Sharding)))
            # walk template subtrees under each prefix leaf
            def expand(prefix, subtree):
                n = len(jax.tree_util.tree_leaves(subtree))
                if prefix is None or isinstance(prefix, jax.sharding.Sharding):
                    flat_s.extend([prefix] * n)
                else:
                    # dict children must follow JAX's sorted-key flat order
                    kids_p = list(sorted(prefix.items())
                                  if isinstance(prefix, dict)
                                  else enumerate(prefix))
                    kids_t = (subtree.items() if isinstance(subtree, dict)
                              else enumerate(subtree))
                    tmap = dict(kids_t)
                    for k, pv in kids_p:
                        expand(pv, tmap[k])
            expand(shardings, state_template)
        else:
            flat_s = [None] * len(flat_t)
        for (p, tmpl), shard in zip(flat_t, flat_s):
            arr = np.load(d / f"arr_{have[jax.tree_util.keystr(p)]}.npy")
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"{jax.tree_util.keystr(p)}: shape "
                                 f"{arr.shape} != template {tmpl.shape}")
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
