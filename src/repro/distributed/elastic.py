"""Elastic scaling: re-mesh + checkpoint reshard after node failures.

Recovery path at scale: a heartbeat monitor (``fault.py``) detects dead
hosts → the launcher computes the largest healthy mesh (keeping the model
axis intact; data/pod axes shrink) → the latest checkpoint is restored with
the NEW mesh's shardings (CheckpointManager.restore with shardings) → the
train step is re-lowered for the new mesh → training resumes.  Batch
geometry stays constant by raising grad-accumulation microbatches to cover
the lost data-parallel ranks.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.launch.mesh import make_mesh_for_devices


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_devices: int
    mesh_shape: tuple
    microbatch_scale: int     # multiply cfg.microbatches by this


def plan_remesh(n_healthy: int, *, model_parallel: int = 16,
                original_data: int = 16, original_pods: int = 1) -> ElasticPlan:
    """Largest usable mesh after failures.

    Keeps the tensor-parallel degree (model-sharded weights can't reshard
    cheaply mid-run); shrinks data/pod to the largest power-of-two fit; the
    global batch is preserved by scaling microbatches.
    """
    if n_healthy < model_parallel:
        raise ValueError(
            f"{n_healthy} healthy chips < model_parallel={model_parallel}")
    data = n_healthy // model_parallel
    # largest power of two ≤ data (keeps batch divisibility)
    d = 1
    while d * 2 <= data:
        d *= 2
    orig = original_data * max(1, original_pods)
    assert orig % d == 0 or d % orig == 0
    scale = max(1, orig // d)
    return ElasticPlan(n_devices=d * model_parallel,
                       mesh_shape=(d, model_parallel),
                       microbatch_scale=scale)


def remesh(plan: ElasticPlan) -> jax.sharding.Mesh:
    return make_mesh_for_devices(plan.n_devices,
                                 model_parallel=plan.mesh_shape[-1])
