"""Sharding rules: parameter/cache/batch pytrees → NamedShardings.

Layout (MaxText-style 2-D sharding):
- tensor-parallel axis ``model``: attention heads, MLP hidden, vocab, experts
- FSDP axis ``data`` (plus ``pod`` when present): the non-TP dimension of
  every large parameter and both Adam moments — ZeRO-3 on top of TP, so
  per-chip parameter state is O(params / n_chips)
- batch axis for activations: ``("pod", "data")``

Rules are path-regex driven (t5x-style), with divisibility guards: a dim is
only sharded if the mesh axis divides it (MQA kv_heads=1 stays replicated).
Scan-stacked trees ("cycles", "enc_layers", "dec_layers") get the leading
layer axis unsharded automatically.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes(mesh: Mesh, *names: str) -> tuple[str, ...]:
    return tuple(n for n in names if n in mesh.axis_names)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return _axes(mesh, "pod", "data")


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return _axes(mesh, "pod", "data")


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _guard(mesh: Mesh, spec_entries, shape) -> P:
    """Drop sharding on dims the mesh axes don't divide."""
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None or dim % _size(mesh, entry) != 0:
            out.append(None)
        else:
            out.append(entry)
    return P(*out)


# (regex on '/'-joined path, spec builder given (mesh, shape))
def _param_rules(mesh: Mesh):
    F = fsdp_axes(mesh)
    return [
        # embeddings / unembedding
        (r"embed$", lambda s: (("model",), F)),
        (r"lm_head$", lambda s: (F, ("model",))),
        # attention & MLA projections
        (r"(wq|wk|wv)$", lambda s: (F, ("model",))),
        (r"wo$", lambda s: (("model",), F)),
        (r"(bq|bk|bv)$", lambda s: (("model",),)),
        (r"w_dkv$", lambda s: (F, None)),
        (r"w_kpe$", lambda s: (F, None)),
        (r"(w_uk|w_uv)$", lambda s: (None, ("model",))),
        # dense MLP
        (r"(w_gate|w_up)$", lambda s: (F, ("model",)) if len(s) == 2 else None),
        (r"w_down$", lambda s: (("model",), F) if len(s) == 2 else None),
        # MoE: experts axis = EP over model
        (r"router$", lambda s: (F, None)),
        (r"experts?.*|.*moe.*", lambda s: None),  # placeholder, refined below
        # RG-LRU
        (r"(w_in|w_gate)$", lambda s: (F, ("model",))),
        (r"w_out$", lambda s: (("model",), F)),
        (r"conv_w$", lambda s: (None, ("model",))),
        (r"(w_rgate|b_rgate|w_igate|b_igate|lam|conv_b)$",
         lambda s: (("model",),)),
        # SSD extras
        (r"(a_log|dt_bias|d_skip)$", lambda s: (None,)),
        (r"(out_norm|kv_norm)$", lambda s: (None,)),
    ]


def _moe_spec(name: str, shape, mesh: Mesh):
    """Expert-stacked tensors [E, D, F] / [E, F, D]: EP over model."""
    F = fsdp_axes(mesh)
    if name.endswith(("w_gate", "w_up")):
        return (("model",), F, None)
    if name.endswith("w_down"):
        return (("model",), None, F)
    return None


def param_spec(path: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf addressed by its '/'-path."""
    # leading stacked-layer axes: cycles / enc_layers / dec_layers
    n_stack = len(re.findall(r"(cycles|enc_layers|dec_layers)", path))
    core_shape = shape[n_stack:]
    name = path.split("/")[-1]

    spec = None
    if "/shared/" in path or path.endswith("shared"):
        # shared experts = dense MLP rules
        if name in ("w_gate", "w_up"):
            spec = (fsdp_axes(mesh), ("model",))
        elif name == "w_down":
            spec = (("model",), fsdp_axes(mesh))
    elif len(core_shape) == 3:
        spec = _moe_spec(name, core_shape, mesh)
    if spec is None:
        for pat, builder in _param_rules(mesh):
            if re.search(pat, name):
                spec = builder(core_shape)
                break
    if spec is None:
        # default: replicate small leaves, FSDP large matrices
        if len(core_shape) == 2 and core_shape[0] * core_shape[1] > 1 << 20:
            spec = (fsdp_axes(mesh), None)
        else:
            spec = (None,) * len(core_shape)
    if spec is not None and len(spec) != len(core_shape):
        spec = (None,) * len(core_shape)
    full = (None,) * n_stack + tuple(spec)
    return _guard(mesh, full, shape)


def _path_str(path) -> str:
    parts = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            parts.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def tree_shardings(tree: Any, mesh: Mesh, spec_fn) -> Any:
    """Map a pytree of ShapeDtypeStructs/arrays to NamedShardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    shardings = [NamedSharding(mesh, spec_fn(_path_str(p), l.shape, mesh))
                 for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def params_shardings(params: Any, mesh: Mesh, *, fsdp: bool = True) -> Any:
    """``fsdp=False``: TP-only placement (params replicated over data/pod,
    sharded over model) — the inference layout: no per-layer FSDP
    all-gathers, at the cost of params/TP_degree memory per chip.  Used by
    the prefill/decode hillclimb (§Perf: ``infer_tp``)."""
    if fsdp:
        return tree_shardings(params, mesh, param_spec)

    dp = set(dp_axes(mesh))

    def tp_only(path: str, shape, m: Mesh) -> P:
        spec = param_spec(path, shape, m)
        entries = []
        for e in spec:
            es = (e,) if isinstance(e, str) else (e or ())
            keep = tuple(a for a in es if a not in dp)
            entries.append(keep if keep else None)
        return P(*entries)

    return tree_shardings(params, mesh, tp_only)


# ---------------------------------------------------------------- caches
def cache_spec(path: str, shape, mesh: Mesh) -> P:
    """KV / recurrent-state caches: batch over dp axes, heads over model."""
    dp = dp_axes(mesh)
    n_stack = 1 if "cycles" in path or "dec" in path.split("/")[0] else 0
    core = shape[n_stack:]
    name = path.split("/")[-1]
    if name in ("k", "v", "cross_k", "cross_v"):      # [B, L, Hkv, Dh]
        # length-sharded over model: KV-head counts (8, 2, 1) don't divide a
        # 16-way TP axis, but the 32k cache length does — attention reduces
        # over L, so softmax/output become cheap partial-reduce all-reduces
        # while the cache itself shards 256-way (batch x length)
        spec = (dp, ("model",), None, None)
    elif name in ("kv_c", "kpe"):                     # [B, L, R]
        spec = (dp, None, None)
    elif name == "state":                             # [B, H, P, N]
        spec = (dp, ("model",), None, None)
    elif name == "conv":                              # [B, W-1, C]
        spec = (dp, None, ("model",))
    elif name == "h":                                 # [B, dr]
        spec = (dp, ("model",))
    else:
        spec = (None,) * len(core)
    full = (None,) * n_stack + tuple(spec)
    return _guard(mesh, full, shape)


def cache_shardings(cache: Any, mesh: Mesh) -> Any:
    return tree_shardings(cache, mesh, cache_spec)


# ---------------------------------------------------------------- batches
def batch_spec(path: str, shape, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    if len(shape) == 0:
        return P()
    spec = (dp,) + (None,) * (len(shape) - 1)
    return _guard(mesh, spec, shape)


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    return tree_shardings(batch, mesh, batch_spec)


# ------------------------------------------------------- activation anchors
def _current_mesh() -> Mesh | None:
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:
        return None


def constrain(x, *entries):
    """``with_sharding_constraint`` that degrades to identity outside a mesh
    context and drops axis names the current mesh doesn't have / sizes that
    don't divide.  Entries use logical tokens: "dp" (batch = pod+data),
    "model", "data", None."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    resolved = []
    for dim, e in zip(x.shape, entries):
        if e == "dp":
            e = dp_axes(mesh) or None
        elif isinstance(e, str) and e not in mesh.axis_names:
            e = None
        if e is not None and dim % _size(mesh, e) != 0:
            e = None
        resolved.append(e)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
