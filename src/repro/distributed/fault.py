"""Failure & straggler detection hooks for the launcher.

This is the host-side control plane: it never enters jitted code.  On a real
cluster each host runs a heartbeat thread; the coordinator aggregates and
triggers the elastic re-mesh (distributed/elastic.py).  The detector logic is
fully testable off-cluster.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque


@dataclasses.dataclass
class HostState:
    last_heartbeat: float
    step_times: deque        # recent per-step wall times


class FaultMonitor:
    """Tracks per-host heartbeats and per-step times.

    - ``dead_hosts``: no heartbeat for ``timeout`` seconds.
    - ``stragglers``: hosts whose rolling median step time exceeds
      ``straggler_factor`` x the cluster median (persistent slowness — the
      launcher responds by excluding the host at the next re-mesh, the
      standard mitigation when checkpoint-restart is cheap).
    """

    def __init__(self, hosts: list[str], *, timeout: float = 60.0,
                 straggler_factor: float = 2.0, window: int = 16):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        now = time.monotonic()
        self.hosts = {h: HostState(now, deque(maxlen=window)) for h in hosts}

    def heartbeat(self, host: str, step_time: float | None = None,
                  now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        st = self.hosts[host]
        st.last_heartbeat = now
        if step_time is not None:
            st.step_times.append(step_time)

    def dead_hosts(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, st in self.hosts.items()
                if now - st.last_heartbeat > self.timeout]

    @staticmethod
    def _median(xs) -> float:
        xs = sorted(xs)
        return xs[len(xs) // 2] if xs else 0.0

    def stragglers(self) -> list[str]:
        medians = {h: self._median(st.step_times)
                   for h, st in self.hosts.items() if st.step_times}
        if len(medians) < 2:
            return []
        cluster = self._median(list(medians.values()))
        if cluster <= 0:
            return []
        return [h for h, m in medians.items()
                if m > self.straggler_factor * cluster]

    def healthy_hosts(self, now: float | None = None) -> list[str]:
        dead = set(self.dead_hosts(now=now)) | set(self.stragglers())
        return [h for h in self.hosts if h not in dead]
