"""Failure & straggler detection hooks for the launcher AND the serving
dispatch workers.

This is the host-side control plane: it never enters jitted code.  On a real
cluster each host runs a heartbeat thread; the coordinator aggregates and
triggers the elastic re-mesh (distributed/elastic.py).  In-process, the
serving layer runs one :class:`FaultMonitor` over its dispatch worker(s):
every micro-batch heartbeats with its step time, and
``ServingEngine.dispatch_stats()["health"]`` surfaces :meth:`snapshot` — the
liveness/straggler view an operator (or the chaos bench) reads.  The
detector logic is fully testable off-cluster.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque


@dataclasses.dataclass
class HostState:
    last_heartbeat: float
    step_times: deque        # recent per-step wall times


class FaultMonitor:
    """Tracks per-host heartbeats and per-step times.

    - ``dead_hosts``: no heartbeat for ``timeout`` seconds.
    - ``stragglers``: hosts whose rolling median step time exceeds
      ``straggler_factor`` x the cluster median (persistent slowness — the
      launcher responds by excluding the host at the next re-mesh, the
      standard mitigation when checkpoint-restart is cheap).
    """

    def __init__(self, hosts: list[str], *, timeout: float = 60.0,
                 straggler_factor: float = 2.0, window: int = 16):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.window = window
        now = time.monotonic()
        self.hosts = {h: HostState(now, deque(maxlen=window)) for h in hosts}

    def ensure_host(self, host: str, now: float | None = None) -> None:
        """Start tracking ``host`` if it is new (elastic join / a serving
        engine growing its dispatch-worker pool)."""
        if host not in self.hosts:
            now = time.monotonic() if now is None else now
            self.hosts[host] = HostState(now, deque(maxlen=self.window))

    def heartbeat(self, host: str, step_time: float | None = None,
                  now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        self.ensure_host(host, now=now)
        st = self.hosts[host]
        st.last_heartbeat = now
        if step_time is not None:
            st.step_times.append(step_time)

    def dead_hosts(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, st in self.hosts.items()
                if now - st.last_heartbeat > self.timeout]

    @staticmethod
    def _median(xs) -> float:
        xs = sorted(xs)
        return xs[len(xs) // 2] if xs else 0.0

    def stragglers(self) -> list[str]:
        medians = {h: self._median(st.step_times)
                   for h, st in self.hosts.items() if st.step_times}
        if len(medians) < 2:
            return []
        cluster = self._median(list(medians.values()))
        if cluster <= 0:
            return []
        return [h for h, m in medians.items()
                if m > self.straggler_factor * cluster]

    def healthy_hosts(self, now: float | None = None) -> list[str]:
        dead = set(self.dead_hosts(now=now)) | set(self.stragglers())
        return [h for h in self.hosts if h not in dead]

    def snapshot(self, now: float | None = None) -> dict:
        """One JSON-able view of the monitored fleet: per-host heartbeat age
        and rolling median step time, plus the dead/straggler/healthy
        classification — the ``dispatch_stats()["health"]`` surface."""
        now = time.monotonic() if now is None else now
        return {
            "hosts": {
                h: {
                    "heartbeat_age_s": now - st.last_heartbeat,
                    "median_step_s": self._median(st.step_times),
                    "steps": len(st.step_times),
                }
                for h, st in self.hosts.items()
            },
            "dead": self.dead_hosts(now=now),
            "stragglers": self.stragglers(),
            "healthy": self.healthy_hosts(now=now),
        }
