"""GPipe-style pipeline parallelism over a dedicated ``pipe`` mesh axis.

For depth ranges where pure FSDP+TP stops scaling (n_layers >> chips per
pod), layers are split into S stages; microbatches stream through stages via
``collective_permute`` on the pipe axis (shard_map SPMD-pipelining, the
jax-native equivalent of the paper's NoC-streamed task queues).

Schedule: classic GPipe fill-drain with M microbatches over S stages —
bubble fraction (S-1)/(M+S-1).  The per-stage body is any ``fn(params, x)
-> x``; stage parameters live only on their stage's devices (the ``pipe``
axis shards the stacked stage-parameter pytree).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def pipeline_apply(
    mesh: Mesh,
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,        # pytree stacked on leading axis = n_stages
    x: jax.Array,             # [M_microbatches, mb, ...] inputs
) -> jax.Array:
    """Run x through S pipeline stages; returns outputs [M, mb, ...].

    SPMD formulation: every device holds ONE stage's params (pipe axis).
    At tick t, stage s processes microbatch (t - s); between ticks,
    activations shift one stage right via collective_permute.
    """
    n_stages = mesh.shape["pipe"]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params, xs):
        # params: this stage's slice (leading axis of size 1 under shard_map)
        params = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index("pipe")
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)          # in-flight activation
        outputs = jnp.zeros_like(xs)                   # stage S-1 collects

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (if any left)
            mb_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            state = jnp.where(stage_id == 0,
                              jnp.where(t < n_micro, mb_in, state), state)
            # every stage applies its layer block
            y = stage_fn(params, state)
            # last stage emits microbatch (t - S + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (t - (n_stages - 1) >= 0) & (stage_id == n_stages - 1)
            outputs = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(
                    outputs, y, out_idx, axis=0),
                outputs)
            # shift activations one stage to the right
            y_next = jax.lax.ppermute(y, "pipe", perm)
            return (y_next, outputs), None

        (state, outputs), _ = jax.lax.scan(
            tick, (state, outputs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them back
        src = n_stages - 1
        outputs = jax.lax.psum(
            jnp.where(stage_id == src, outputs, jnp.zeros_like(outputs)),
            "pipe")
        return outputs

    spec_params = jax.tree.map(lambda _: P("pipe"), stage_params)
    fn = compat.shard_map(per_stage, mesh=mesh,
                          in_specs=(spec_params, P()),
                          out_specs=P(),
                          check=False)
    return fn(stage_params, x)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
