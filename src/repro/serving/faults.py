"""FaultInjector — seedable, deterministic chaos for the serving stack.

Production serving must *degrade*, not crash: a poison request should fail
alone, a flaky compiled program should fall back to the eager executor, a
truncated snapshot should cold-start.  None of those paths is trustworthy
unless it runs in CI, and none of them runs in CI unless failures can be
produced on demand.  This module is that switch: every layer of the stack
carries named probe points, and an armed :class:`FaultInjector` decides —
deterministically, from a seed — which probes raise an
:class:`InjectedFault` (or stall, for straggler/deadline testing).

Instrumented sites (``KNOWN_SITES``):

====================  ====================================================
``plan``              ``DynasparseEngine.plan`` entry (analysis phase)
``lower``             single-device descriptor lowering (``build_dispatch``)
``pack``              structure/activation packing
                      (``_packed_structure`` build,
                      ``build_activation_dispatch``)
``execute``           ``DynasparseEngine.execute`` entry (eager execute)
``shard_lower``       sharded descriptor lowering + halo-exchange schedule
                      compilation (``build_sharded_dispatch``)
``shard_exec``        sharded compiled execute entry
                      (``shard_exec.execute_sharded`` — the one jitted
                      ``shard_map`` call of a mesh engine)
``compiled``          ``CompiledModel.__call__`` (whole-model compiled
                      execute)
``request``           per-request probe inside the serving dispatch — the
                      poison-request site (``detail`` carries
                      ``req:<request_id>;``; pair with ``match="req:7;"`` —
                      the ``;`` terminator keeps id 7 from matching 71)
``dispatch``          serving dispatch-worker entry (use ``delay_s`` here
                      to manufacture stragglers/deadline misses)
``snapshot_save``     ``SharedPlanCache.save`` (before the atomic rename —
                      a fault here must never corrupt the target file)
``snapshot_load``     ``SharedPlanCache.load`` (must degrade to a logged
                      cold start, never crash the restart path)
====================  ====================================================

Determinism: each site owns an independent ``numpy`` Generator seeded from
``(seed, site)``, consumed once per rate draw — with a fixed seed and a
deterministic probe order (serving dispatch is single-worker), the same
faults fire at the same probes on every run, so a chaos scenario is
reproducible and its gates are not flaky.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from collections import Counter

import numpy as np

KNOWN_SITES = frozenset({
    "plan", "lower", "pack", "execute", "compiled",
    "shard_lower", "shard_exec",
    "request", "dispatch", "snapshot_save", "snapshot_load",
})


class InjectedFault(RuntimeError):
    """A failure manufactured by a :class:`FaultInjector` probe."""

    def __init__(self, site: str, detail: str = "", n: int = 0):
        self.site = site
        self.detail = detail
        self.n = n           # per-site probe index the fault fired at
        msg = f"injected fault at site {site!r}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg + f" [probe #{n}]")


class DeadlineExceeded(RuntimeError):
    """A request missed its ``ServingConfig.request_timeout`` deadline.

    Raised to the submitter by ``ServingEngine.infer``; the request's
    ``RequestStats.error`` carries the same message, so stragglers are
    observable in the stats instead of hanging ``serve()``."""


@dataclasses.dataclass
class _Arm:
    """One armed failure rule on a site."""
    rate: float = 1.0           # firing probability per eligible probe
    count: int | None = None    # max fires (None = unlimited)
    after: int = 0              # skip the first `after` eligible probes
    delay_s: float = 0.0        # > 0: stall instead of raising
    match: str | None = None    # substring filter on the probe's detail
    fired: int = 0
    seen: int = 0               # eligible (match-passing) probes observed


class FaultInjector:
    """Deterministic, seedable failure/delay injection at named sites.

    Arm failure rules with :meth:`arm`, thread the injector through the
    stack (``DynasparseEngine(faults=...)``, ``ServingConfig(faults=...)``,
    ``SharedPlanCache(faults=...)``), and every instrumented layer will
    consult it via :meth:`probe`.  Thread-safe: the serving dispatch worker
    and the event loop may probe concurrently.
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._arms: dict[str, list[_Arm]] = {}
        self._rngs: dict[str, np.random.Generator] = {}
        self._lock = threading.RLock()
        self.probes: Counter = Counter()   # probes observed per site
        self.fired: Counter = Counter()    # faults raised per site
        self.delayed: Counter = Counter()  # delays served per site

    # --------------------------------------------------------------- setup
    def arm(self, site: str, *, rate: float = 1.0, count: int | None = None,
            after: int = 0, delay_s: float = 0.0,
            match: str | None = None) -> "FaultInjector":
        """Arm one failure rule; returns ``self`` for chaining.

        ``rate`` is the per-probe firing probability (1.0 = every eligible
        probe); ``count`` bounds total fires; ``after`` skips the first N
        eligible probes (lets a warmup pass run clean); ``delay_s > 0``
        sleeps instead of raising (straggler injection); ``match`` restricts
        the rule to probes whose detail contains the substring (poison
        requests: ``match="req:7;"``).
        """
        if site not in KNOWN_SITES:
            raise ValueError(
                f"unknown fault site {site!r} (instrumented sites: "
                f"{sorted(KNOWN_SITES)})")
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        with self._lock:
            self._arms.setdefault(site, []).append(_Arm(
                rate=rate, count=count, after=after, delay_s=delay_s,
                match=match))
        return self

    def disarm(self, site: str | None = None) -> None:
        """Drop every rule on ``site`` (or on all sites)."""
        with self._lock:
            if site is None:
                self._arms.clear()
            else:
                self._arms.pop(site, None)

    # --------------------------------------------------------------- probe
    def _rng(self, site: str) -> np.random.Generator:
        rng = self._rngs.get(site)
        if rng is None:
            # independent, reproducible stream per site: the firing pattern
            # at one site never shifts because another site probed more
            rng = np.random.default_rng(
                (self.seed, zlib.crc32(site.encode())))
            self._rngs[site] = rng
        return rng

    def probe(self, site: str, detail: str = "") -> None:
        """Consult the injector at an instrumented site.

        Raises :class:`InjectedFault` (or sleeps, for delay rules) when an
        armed rule fires; a no-op otherwise (and always a no-op on an
        injector with nothing armed — the probes are cheap enough to leave
        in production code paths).
        """
        with self._lock:
            self.probes[site] += 1
            n = self.probes[site]
            arms = self._arms.get(site)
            if not arms:
                return
            for a in arms:
                if a.match is not None and a.match not in detail:
                    continue
                a.seen += 1
                if a.seen <= a.after:
                    continue
                if a.count is not None and a.fired >= a.count:
                    continue
                if a.rate < 1.0 and self._rng(site).random() >= a.rate:
                    continue
                a.fired += 1
                if a.delay_s > 0.0:
                    self.delayed[site] += 1
                    delay = a.delay_s
                    break
                self.fired[site] += 1
                raise InjectedFault(site, detail=detail, n=n)
            else:
                return
        # sleep OUTSIDE the lock: a stalled dispatch worker must not block
        # other threads' probes (that would serialize the chaos)
        time.sleep(delay)

    # ----------------------------------------------------------- telemetry
    def summary(self) -> dict:
        """Per-site probe/fire/delay counts (the bench/test observable)."""
        with self._lock:
            sites = set(self.probes) | set(self.fired) | set(self.delayed)
            return {
                site: {"probes": self.probes[site],
                       "fired": self.fired[site],
                       "delayed": self.delayed[site]}
                for site in sorted(sites)
            }

    @property
    def total_fired(self) -> int:
        with self._lock:
            return sum(self.fired.values())


def probe(faults: "FaultInjector | None", site: str, detail: str = "") -> None:
    """Null-safe probe helper: every instrumented layer calls this with its
    (possibly ``None``) injector, keeping call sites one line."""
    if faults is not None:
        faults.probe(site, detail)
