"""Activation-density sketch — drift revalidation config for serving.

A plan-cache hit reuses the STQ/DTQ assignment built from the FIRST
request's measured feature densities.  That is the intended amortization,
but it is a hazard when traffic drifts (Dynasparse re-decides the kernel
mapping exactly because data sparsity changes at runtime): a near-dense
feature batch served through an assignment measured on sparse features
lands dense work on the block-skip kernels (slow), and vice versa.

The sketch is a strided row sample of the stacked micro-batch feature
matrix (``core.sparsity.sketch_col_density``), compared per col-stripe
against the plan's cached densities (``core.sparsity.density_drift``).
The engine consults it on every plan hit when ``drift_threshold`` is set;
:class:`SketchConfig` is how the serving layer sets it.
"""
from __future__ import annotations

import dataclasses

from repro.core.sparsity import density_drift, sketch_col_density  # noqa: F401 (re-export)


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Revalidation policy applied to the engines a ServingEngine drives.

    ``threshold`` is the max tolerated per-stripe |density gap| before a
    cached plan is re-built (``None`` disables revalidation — raw PR-1
    amortization).  ``max_rows`` bounds the sketch's row sample.
    """
    threshold: float | None = 0.25
    max_rows: int = 256

    def apply(self, engine) -> None:
        engine.drift_threshold = self.threshold
        engine.sketch_rows = self.max_rows
