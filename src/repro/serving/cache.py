"""SharedPlanCache — the process-wide, multi-graph, persistent plan cache.

Serving amortizes the paper's preprocessing across *every* request the
process handles, not just requests of one engine: all ``ServingEngine``
instances (and any ``DynasparseEngine`` constructed with it) share one
byte-accounted LRU store, so two models serving the same graph share one
packed adjacency, and a cold graph's packed stripes are evicted before a hot
graph's plans.

Keying: graphs are registered under a :class:`GraphKey` —
``(fingerprint, shape, dtype)`` — where the fingerprint is the O(nnz) content
digest also used by the plan-level keys, so a registry entry and its cache
entries can never disagree about which adjacency they describe.

Persistence: ``save()`` snapshots every cache entry (device arrays are
pulled back to host numpy) plus the graph registry; ``load()`` restores it,
so a serving restart skips re-analysis and re-packing entirely — the
GraphAGILE "compile ahead of execution" property across process lifetimes.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import pickle
import threading
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger(__name__)

from repro.core.dispatch import CompiledDispatch
from repro.core.plancache import PlanCache, StructureEntry, key_mentions
from repro.core.primitives import SparseCOO
from repro.core.plancache import coo_fingerprint

# v2: DispatchGeometry grew the static ``eps`` field and the activation-
# dispatch entry kind was added — v1 snapshots would restore geometry
# objects missing attributes, so they are rejected instead of resurrected.
# v3: ActivationGeometry grew the per-stripe ``caps`` budget field and the
# calibration entry kind (``CalibratedModel`` measurements) was added —
# same rejection rationale for v2 snapshots.
# v4: mesh-sharded dispatch — KernelPlan grew ``placement``, Task grew
# ``device``, ScheduleReport grew ``per_device``, and the sharded-dispatch
# entry kind was added; v3 snapshots would restore plans whose dataclasses
# miss those fields.
# v5: owned-operand halo sharding — ShardedDispatch grew
# ``supports``/``halo``/``operand_sharding``/``operand_bytes``, its
# ``arrays`` carry the ``hx_*`` exchange-schedule index streams, sharded
# cache keys carry the operand-sharding mode, and placed plan digests hash
# the ownership geometry; v4 ``_SHARD`` entries (and their keys) would
# replay the replicated layout under halo-mode keys, so v4 snapshots
# cold-start exactly as other stale versions do.
_PERSIST_VERSION = 5


@dataclasses.dataclass(frozen=True)
class GraphKey:
    """Identity of a registered graph: content fingerprint + geometry."""
    fingerprint: str
    shape: tuple[int, int]
    dtype: str

    @classmethod
    def of(cls, adj: SparseCOO) -> "GraphKey":
        return cls(fingerprint=coo_fingerprint(adj),
                   shape=tuple(adj.shape),
                   dtype=str(np.asarray(adj.vals).dtype))


def _to_host(obj):
    """Recursively pull jax arrays back to host numpy (pickle-safe)."""
    if isinstance(obj, jax.Array):
        return np.asarray(obj)
    if isinstance(obj, np.ndarray) or obj is None or isinstance(
            obj, (bool, int, float, complex, str, bytes)):
        return obj
    if isinstance(obj, dict):
        return {k: _to_host(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_to_host(v) for v in obj]
    if isinstance(obj, tuple):
        return tuple(_to_host(v) for v in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.replace(obj, **{
            f.name: _to_host(getattr(obj, f.name))
            for f in dataclasses.fields(obj)})
    return obj


def _struct_to_device(entry: StructureEntry) -> StructureEntry:
    """Re-upload a restored structure entry's payload to the device ONCE at
    load time — the hot path must keep the packed stripes device-resident,
    not pay a host->device transfer per micro-batch."""
    stripes = {
        i: jax.tree_util.tree_map(jnp.asarray, bcsr)
        for i, bcsr in entry.stripes.items()}
    dense = None if entry.dense is None else jnp.asarray(entry.dense)
    return StructureEntry(stripes=stripes, dense=dense)


def _dispatch_to_device(d):
    """Re-upload a restored compiled/activation dispatch's descriptor arrays
    (and, for :class:`CompiledDispatch`, pooled block payloads) — a
    restarted serving process replays zero descriptor lowering."""
    return dataclasses.replace(
        d, arrays={k: jnp.asarray(v) for k, v in d.arrays.items()})


class SharedPlanCache(PlanCache):
    """Thread-safe multi-graph :class:`PlanCache` with save/load.

    Defaults are serving-scale: room for many graphs' plans under one byte
    budget.  All mutating/reading accessors take an RLock so engines on
    worker threads can share one instance.
    """

    def __init__(self, capacity: int = 4096,
                 max_bytes: int | None = 256 * 1024 * 1024,
                 faults: object = None):
        super().__init__(capacity=capacity, max_bytes=max_bytes)
        self._lock = threading.RLock()
        self._graphs: dict[str, GraphKey] = {}   # graph_id -> key
        # optional repro.serving.faults.FaultInjector probed at the
        # snapshot_save / snapshot_load sites (chaos-testing the restart
        # path); assignable after construction too
        self.faults = faults

    # ----------------------------------------------------- locked accessors
    # The get-or-compute methods are locked as a WHOLE (not just the
    # primitive _get/_put) so two worker threads can never pack/analyze the
    # same structure twice or interleave a replace between a miss and its
    # put — the RLock makes the nested primitive locking reentrant.
    def _get(self, kind, key):
        with self._lock:
            return super()._get(kind, key)

    def _put(self, kind, key, value):
        with self._lock:
            super()._put(kind, key, value)

    def recharge(self, kind, key):
        with self._lock:
            super().recharge(kind, key)

    def get_plan(self, key):
        with self._lock:
            return super().get_plan(key)

    def put_plan(self, key, plan):
        with self._lock:
            super().put_plan(key, plan)

    def row_density(self, key, compute):
        with self._lock:
            return super().row_density(key, compute)

    def structure(self, key, compute):
        with self._lock:
            return super().structure(key, compute)

    def dispatch(self, key, compute):
        with self._lock:
            return super().dispatch(key, compute)

    def dispatch_count(self):
        with self._lock:
            return super().dispatch_count()

    def sharded_dispatch(self, key, compute):
        with self._lock:
            return super().sharded_dispatch(key, compute)

    def sharded_count(self):
        with self._lock:
            return super().sharded_count()

    def activation_dispatch(self, key, compute):
        with self._lock:
            return super().activation_dispatch(key, compute)

    def activation_count(self):
        with self._lock:
            return super().activation_count()

    def calibration(self, key, compute):
        with self._lock:
            return super().calibration(key, compute)

    def calibration_count(self):
        with self._lock:
            return super().calibration_count()

    def purge_fingerprint(self, fingerprint):
        with self._lock:
            return super().purge_fingerprint(fingerprint)

    def items(self):
        with self._lock:
            yield from list(super().items())

    def plan_count(self):
        with self._lock:
            return super().plan_count()

    def clear(self):
        with self._lock:
            super().clear()
            self._graphs.clear()

    # ------------------------------------------------------- graph registry
    def register_graph(self, graph_id: str, adj: SparseCOO) -> GraphKey:
        """Register (or re-register) a graph under ``graph_id``.

        Re-registering the same id with DIFFERENT content purges the old
        content's cache entries — plans, packed structures and compiled
        dispatches — unless another registered id still maps to that
        content.  Waiting for LRU aging is not enough: ``save`` would
        snapshot the stale entries and every later ``load`` would resurrect
        them (including device-resident ``CompiledDispatch`` payloads),
        growing the snapshot by one dead graph per re-registration and
        squatting in the byte budget forever.
        """
        key = GraphKey.of(adj)
        with self._lock:
            old = self._graphs.get(graph_id)
            self._graphs[graph_id] = key
            if (old is not None and old.fingerprint != key.fingerprint
                    and not any(k.fingerprint == old.fingerprint
                                for k in self._graphs.values())):
                self.purge_fingerprint(old.fingerprint)
        return key

    def graph_key(self, graph_id: str) -> GraphKey | None:
        with self._lock:
            return self._graphs.get(graph_id)

    @property
    def graphs(self) -> dict[str, GraphKey]:
        with self._lock:
            return dict(self._graphs)

    # --------------------------------------------------------- persistence
    def save(self, path: str) -> dict:
        """Snapshot every entry + the graph registry to ``path``.

        Device arrays are converted to host numpy; entry order (LRU) is
        preserved.  Returns a small manifest (entry count, bytes) for logs.

        The write is ATOMIC: the payload is pickled to a same-directory
        temp file and moved into place with ``os.replace``, so a process
        crashing mid-save (power loss, OOM kill, injected fault) can never
        leave a truncated snapshot where the next restart would trip over
        it — the previous snapshot, if any, survives intact.
        """
        with self._lock:
            entries = [((kind, key), _to_host(value))
                       for (kind, key), value in self.items()]
            payload = {
                "version": _PERSIST_VERSION,
                "entries": entries,
                "graphs": dict(self._graphs),
            }
            manifest = {"entries": len(entries), "bytes": self.bytes_used,
                        "graphs": len(self._graphs)}
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "wb") as f:
                if self.faults is not None:
                    self.faults.probe("snapshot_save", detail=path)
                pickle.dump(payload, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return manifest

    def load(self, path: str) -> dict:
        """Restore a snapshot saved by :meth:`save` into this cache.

        Loaded entries land in saved LRU order *below* anything already
        cached (existing entries stay most-recent).  Stats are not restored
        — hit/miss counting starts fresh, which is what a restarted serving
        process wants to observe — except ``snapshot_errors``, which counts
        against THIS process.

        An unusable snapshot — truncated, corrupt, wrong pickle, or a
        version this build does not speak — must never crash the serving
        startup path it exists to accelerate: it degrades to a logged COLD
        START.  The cache is left exactly as it was, ``snapshot_errors`` is
        incremented, and the returned manifest carries the reason under
        ``"error"`` (version mismatches keep their explicit wanted/got
        message there) with ``cold_start=True``.

        Live registrations win over the snapshot: a graph id already
        registered in THIS process keeps its mapping, and snapshot entries
        whose content key belongs to an id the live registry has since
        re-bound to different content are SKIPPED — restoring them would
        resurrect a stale ``CompiledDispatch`` (old adjacency's descriptors
        and block payloads) under the superseded content key.
        """
        try:
            if self.faults is not None:
                self.faults.probe("snapshot_load", detail=path)
            with open(path, "rb") as f:
                payload = pickle.load(f)
            if not isinstance(payload, dict):
                raise ValueError(
                    f"plan-cache snapshot payload is "
                    f"{type(payload).__name__}, not a dict")
            if payload.get("version") != _PERSIST_VERSION:
                raise ValueError(
                    f"unsupported plan-cache snapshot version "
                    f"{payload.get('version')!r} (want {_PERSIST_VERSION})")
            snap_graphs: dict[str, GraphKey] = payload["graphs"]
            snap_entries = list(payload["entries"])
        except Exception as exc:
            with self._lock:
                self.stats.snapshot_errors += 1
            logger.warning(
                "plan-cache snapshot %s unusable (%s: %s) — cold start",
                path, type(exc).__name__, exc)
            return {"entries": 0, "stale_skipped": 0, "mesh_skipped": 0,
                    "graphs": 0, "cold_start": True,
                    "error": f"{type(exc).__name__}: {exc}"}
        with self._lock:
            # fingerprints the live registry has superseded — unless some
            # current (or non-conflicting snapshot) id still maps to them
            stale = {key.fingerprint for gid, key in snap_graphs.items()
                     if gid in self._graphs
                     and self._graphs[gid].fingerprint != key.fingerprint}
            stale -= {k.fingerprint for k in self._graphs.values()}
            stale -= {key.fingerprint for gid, key in snap_graphs.items()
                      if gid not in self._graphs}

            live = list(self.items())
            self._entries.clear()
            self.bytes_used = 0
            n_live_devices = len(jax.devices())
            loaded = skipped = mesh_skipped = 0
            for (kind, key), value in snap_entries:
                if any(key_mentions(key, fp) for fp in stale):
                    skipped += 1
                    continue
                if kind == self._SHARD and (
                        getattr(value, "n_devices", 1) > n_live_devices):
                    # sharded dispatch from a bigger host: its mesh cannot
                    # be constructed here, so the entry could never be hit
                    # (keys carry the device count) — don't resurrect dead
                    # device payloads into the byte budget (an 8-device
                    # snapshot must not poison a 1-device restart)
                    mesh_skipped += 1
                    continue
                if kind == self._STRUCT:
                    value = _struct_to_device(value)
                elif kind in (self._DISPATCH, self._ACT, self._SHARD):
                    value = _dispatch_to_device(value)
                super()._put(kind, key, value)
                loaded += 1
            for (kind, key), value in live:
                super()._put(kind, key, value)
            for gid, key in snap_graphs.items():
                self._graphs.setdefault(gid, key)
            return {"entries": loaded, "stale_skipped": skipped,
                    "mesh_skipped": mesh_skipped,
                    "graphs": len(snap_graphs), "cold_start": False}


# --------------------------------------------------------------- singleton
_shared: SharedPlanCache | None = None
_shared_lock = threading.Lock()


def get_shared_cache() -> SharedPlanCache:
    """The process-wide cache used by every ServingEngine by default."""
    global _shared
    with _shared_lock:
        if _shared is None:
            _shared = SharedPlanCache()
        return _shared


def set_shared_cache(cache: SharedPlanCache | None) -> None:
    """Swap (or reset, with ``None``) the process-wide cache — tests and
    drivers that need an isolated or pre-loaded instance."""
    global _shared
    with _shared_lock:
        _shared = cache
