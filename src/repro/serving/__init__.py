"""Serving subsystem: async micro-batched GNN inference over a shared
multi-graph plan cache.

    queue ──► density sketch ──► SharedPlanCache ──► batched dispatch

See ``repro.serving.engine`` for the request path (including the
degraded-mode ladder: compiled → eager → bisected per-request retry →
quarantine), ``repro.serving.cache`` for the process-wide cache +
persistence, and ``repro.serving.faults`` for the seeded chaos injector.
"""
from repro.serving.cache import (GraphKey, SharedPlanCache, get_shared_cache,
                                 set_shared_cache)
from repro.serving.engine import (RequestStats, ServingConfig, ServingEngine,
                                  ServingStats, batched_mm, stacked_transport)
from repro.serving.faults import (DeadlineExceeded, FaultInjector,
                                  InjectedFault)
from repro.serving.sketch import SketchConfig

__all__ = [
    "GraphKey", "SharedPlanCache", "get_shared_cache", "set_shared_cache",
    "RequestStats", "ServingConfig", "ServingEngine", "ServingStats",
    "batched_mm", "stacked_transport", "SketchConfig",
    "DeadlineExceeded", "FaultInjector", "InjectedFault",
]
