"""ServingEngine — async micro-batched GNN inference.

The front-end of the serving subsystem: concurrent requests against the
same registered graph are coalesced into one stacked feature matrix and
served with ONE plan/execute pass per model kernel — GraphAGILE's overlay
insight (batch requests through a compiled kernel sequence instead of
replaying the whole pipeline per request) on top of the SharedPlanCache's
amortized preprocessing.

Batching math: a GNN layer is matmuls plus element-wise ops, so ``k``
requests' feature matrices ``h_r`` (each ``N x d``) stack column-wise into
``H = [h_1 | ... | h_k]`` (``N x k·d``).  Aggregation ``Â · H`` distributes
over the column blocks directly; transformation ``H · W`` is computed by
unstacking to ``(k·N, d)`` row form around a single engine matmul.  Block
``r`` of every intermediate therefore equals the per-request computation
bit-for-bit — micro-batched results match ``run_reference`` per request.

Request lifecycle::

    submit ──► per-graph queue ──► micro-batch (≤ max_batch, ≤ max_delay)
           ──► pad to the max_batch stacked width (single-plan serving)
           ──► density sketch revalidates cached plan (replan on drift)
           ──► one plan/execute pass on the dispatch worker thread
           ──► outputs split per request, futures resolved, stats recorded

The plan/execute pass runs on a dedicated single-worker executor, NOT on
the event loop: while a batch computes, the loop keeps accepting and
coalescing the next burst.  Padding partial batches to ``max_batch`` keeps
the engine's kernel geometry constant across traffic shapes, so every
registered graph plans exactly once per distinct model kernel (the
GraphAGILE compile-once/serve-many overlay property).

Degraded-mode serving (the failure half of the lifecycle)::

    compiled program fails   ──► eager batched fallback (degraded_batches)
    eager batch fails        ──► bisect into halves (bisections) until the
                                 poison request fails ALONE
    single request fails     ──► bounded backoff retries (retries), then
                                 quarantine (quarantined) — its future
                                 carries the error, neighbours are served
                                 bit-identically to a fault-free run
    batch straggles/wedges   ──► per-request deadline fails the caller with
                                 DeadlineExceeded (deadline_expired)
    drift→recompile churn    ──► per-graph circuit breaker pins the
                                 last-good program through a cooldown
                                 (breaker_trips)

Fault sites for chaos testing are instrumented throughout (see
serving/faults.py); the dispatch worker heartbeats a
``distributed.fault.FaultMonitor`` exposed via
``dispatch_stats()["health"]``.
"""
from __future__ import annotations

import asyncio
import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DynasparseEngine, EngineReport
from repro.core.primitives import SparseCOO
from repro.distributed.fault import FaultMonitor
from repro.models import gnn
from repro.serving.cache import GraphKey, SharedPlanCache, get_shared_cache
from repro.serving.faults import DeadlineExceeded, FaultInjector
from repro.serving.sketch import SketchConfig


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Micro-batching + revalidation policy of one ServingEngine.

    ``pad_to_max_batch`` (default on) pads a partial micro-batch's stacked
    feature matrix to the ``max_batch`` width before dispatch (replicating
    the batch's own feature columns — see ``_dispatch``) and slices the
    padding columns away on split.  The engine then sees ONE stacked width
    per graph/kernel regardless of traffic shape, so the plan cache holds
    exactly one plan per graph and model kernel — instead of one per
    distinct batch size — and the density sketch never sees a
    traffic-shape-dependent operand.  Column blocks are independent through
    the model zoo (matmuls + element-wise ops), so per-request results are
    unchanged.
    """
    max_batch: int = 8            # requests coalesced per dispatch
    max_delay_s: float = 0.0      # batching window after the first request
    sketch: SketchConfig = SketchConfig()
    pad_to_max_batch: bool = True  # single-plan serving (see class docstring)
    # Whole-model compiled dispatch (default on): the first micro-batch of a
    # (graph, stacked shape) runs eagerly — planning, packing and lowering
    # every kernel — and doubles as the warmup pass of
    # ``models.gnn.compile_model``; every later batch is ONE jitted call with
    # zero host descriptor work.  The input-density sketch invalidates the
    # compiled program on drift (the eager re-run replans, then recompiles).
    # Engines the compiler declines (non-literal, misaligned geometry,
    # eps-thresholded SpMM) transparently stay eager.
    compile_models: bool = True
    # Bound on retained compiled programs (insertion-order eviction): the
    # registry pins descriptor/operand arrays outside the byte-accounted
    # plan cache, so a many-graph engine must not grow it without limit.
    max_compiled: int = 32
    # Sparse-activation block-skip inside compiled programs: activation-side
    # kernels whose warmup plan routed tasks to the sparse engine run on the
    # capacity-padded BlockCSR route (fixed stored-block budget =
    # ``activation_slack`` headroom over the warmup's measured blocks;
    # overflow falls back to a dense GEMM inside the same program).  Off →
    # every activation kernel is one dense Pallas GEMM (PR-4 behaviour).
    activation_skip: bool = True
    activation_slack: float = 1.5
    # per-stripe capacity budgets (each stripe sized from its own warmup
    # need × slack) instead of one uniform max-need budget — cuts padded-
    # slot waste on skewed activations; off restores the uniform budget.
    activation_per_stripe: bool = True
    # Multi-device dispatch: shard each graph's row-stripe bands over a 1-D
    # ("data",) mesh of this many local devices (None = classic
    # single-device engine).  Threads through warmup → compile →
    # drift-replan: the constructed DynasparseEngine plans with a
    # two-level (device, queue) placement and executes compiled kernels
    # under shard_map.  Requires the host to expose that many devices
    # (``launch.mesh.make_data_mesh`` raises otherwise).
    n_devices: int | None = None
    # Dense-operand distribution of the sharded executor: "halo" (default)
    # ships each device only its owned block-rows + the halo its band reads
    # (static ppermute exchange inside the program); "replicate" keeps the
    # full-replication layout — the bitwise correctness oracle.  Ignored
    # without ``n_devices``.
    operand_sharding: str = "halo"
    # ---- degraded-mode serving (fault tolerance policy) -----------------
    # Per-request retry budget once a request has been isolated by the
    # bisection ladder (a failed micro-batch is split in halves until the
    # poison request fails alone); exhausted retries quarantine the request
    # — its future resolves with the error, neighbours are untouched.
    max_retries: int = 1
    # Base of the exponential backoff between per-request retries (seconds,
    # slept on the dispatch worker; attempt ``i`` sleeps ``base * 2**i``).
    retry_backoff_s: float = 0.0
    # Per-request deadline: ``infer()`` raises ``DeadlineExceeded`` (and
    # records the request with a structured error) instead of waiting
    # forever on a straggling batch.  None = no deadline.
    request_timeout: float | None = None
    # Circuit breaker over drift→replan→recompile churn: more than
    # ``breaker_threshold`` compiled-program invalidation events within
    # ``breaker_window_s`` trips the graph's breaker for
    # ``breaker_cooldown_s`` — the last-good compiled program is pinned
    # (drift checks and eager replans suppressed) until the cooldown ends.
    breaker_threshold: int = 3
    breaker_window_s: float = 60.0
    breaker_cooldown_s: float = 30.0
    # Chaos hook: a seeded ``serving.faults.FaultInjector`` threaded through
    # the engine, plan cache and compiled programs.  None (default) = every
    # probe is a no-op attribute check.
    faults: FaultInjector | None = None


@dataclasses.dataclass
class RequestStats:
    """Per-request observability record (the ISSUE's latency/queue-depth)."""
    request_id: int
    graph_id: str
    queue_depth: int              # requests already waiting at enqueue
    batch_size: int = 0           # real requests in the micro-batch (no pad)
    t_queue: float = 0.0          # seconds from enqueue to dispatch
    t_execute: float = 0.0        # micro-batch execute wall (shared)
    latency: float = 0.0          # enqueue -> result available
    report: EngineReport | None = None   # per-request share of the batch
                                         # report (EngineReport.attributed)
    error: str | None = None      # set when the request's batch failed


@dataclasses.dataclass
class ServingStats:
    requests: list[RequestStats] = dataclasses.field(default_factory=list)
    batches: int = 0
    compiled_batches: int = 0     # batches served by a CompiledModel call
    compile_invalidations: int = 0  # compiled programs dropped on input drift
    # raw (unattributed) engine report of every SUCCESSFUL micro-batch, in
    # dispatch order — the per-request `RequestStats.report` is a 1/k share.
    # Failed batches count in `batches` but carry no engine report (their
    # requests are visible via `RequestStats.error`), so len(batch_reports)
    # == batches - failed batches.
    batch_reports: list[EngineReport] = dataclasses.field(default_factory=list)
    # per COMPILED batch with activation-route kernels: aggregated block-skip
    # telemetry {stored, capacity, logical, overflows, skipped_ratio} summed
    # over that batch's activation kernels (the bench gate's surface)
    activation_batches: list[dict] = dataclasses.field(default_factory=list)
    # running aggregates of the same telemetry, so dispatch_stats() stays
    # O(1) instead of re-reducing the per-batch history on every call
    act_overflows: int = 0
    act_skipped_sum: float = 0.0
    act_kernels_last: int = 0
    # ---- degraded-mode telemetry ----------------------------------------
    degraded_batches: int = 0   # compiled call failed → eager fallback served
    bisections: int = 0         # failed micro-batch splits (ladder descents)
    retries: int = 0            # isolated per-request retry attempts
    quarantined: int = 0        # requests failed alone after retry budget
    breaker_trips: int = 0      # drift-churn circuit-breaker activations
    deadline_expired: int = 0   # requests failed by request_timeout

    def record_activation(self, summary: dict) -> None:
        self.activation_batches.append(summary)
        self.act_overflows += summary["overflows"]
        self.act_skipped_sum += summary["skipped_ratio"]
        self.act_kernels_last = summary["kernels"]

    def latency_percentiles(self) -> dict:
        if not self.requests:
            return {"p50": 0.0, "p95": 0.0, "mean": 0.0}
        lat = np.array([r.latency for r in self.requests])
        return {"p50": float(np.percentile(lat, 50)),
                "p95": float(np.percentile(lat, 95)),
                "mean": float(lat.mean())}

    @property
    def mean_batch_size(self) -> float:
        if not self.requests:
            return 0.0
        return len(self.requests) / max(1, self.batches)

    @property
    def errors(self) -> int:
        return sum(1 for r in self.requests if r.error is not None)

    def as_dict(self) -> dict:
        return {"requests": len(self.requests), "batches": self.batches,
                "compiled_batches": self.compiled_batches,
                "compile_invalidations": self.compile_invalidations,
                "errors": self.errors,
                "degraded_batches": self.degraded_batches,
                "bisections": self.bisections,
                "retries": self.retries,
                "quarantined": self.quarantined,
                "breaker_trips": self.breaker_trips,
                "deadline_expired": self.deadline_expired,
                "mean_batch_size": self.mean_batch_size,
                "latency": self.latency_percentiles()}


@dataclasses.dataclass
class _Request:
    features: jnp.ndarray
    future: asyncio.Future
    stats: RequestStats
    t_enqueue: float
    # set once the request's RequestStats has been appended (loop OR worker
    # thread may get there first — deadline expiry races batch completion)
    recorded: bool = False
    # set when the caller stopped waiting (deadline): the dispatcher drops
    # the request instead of spending a batch slot on an abandoned future
    abandoned: bool = False


def stacked_transport(mm: gnn.MM) -> gnn.MM:
    """Wrap an abstract matmul with the stacked-representation transport.

    Sparse x (aggregation): the stacked ``(N, k·d)`` operand feeds one
    kernel — aggregation distributes over the column blocks directly.
    Dense x (transformation): the stacked operand is unstacked to row form
    ``(k·N, d_in)`` around one kernel, so weights are never
    block-diagonalized.  ``k`` is recovered from the width ratio, so the
    same ``mm`` serves every layer of every model.  Trace-pure (shapes
    only), so the whole-model compiler reuses it around the replayed
    kernels.
    """
    def wrapped(x, y, name: str = "kernel"):
        if isinstance(x, SparseCOO):
            return mm(x, y, name=name)
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        d_in = y.shape[0]
        if x.shape[1] == d_in:          # unstacked (k == 1) — plain kernel
            return mm(x, y, name=name)
        if x.shape[1] % d_in:
            raise ValueError(
                f"stacked width {x.shape[1]} is not a multiple of the "
                f"weight fan-in {d_in}")
        k = x.shape[1] // d_in
        n = x.shape[0]
        xr = x.reshape(n, k, d_in).transpose(1, 0, 2).reshape(k * n, d_in)
        z = mm(xr, y, name=name)
        d_out = y.shape[1]
        return z.reshape(k, n, d_out).transpose(1, 0, 2).reshape(n, k * d_out)
    return wrapped


def _activation_summary(diags: list[dict]) -> dict:
    """Aggregate one compiled batch's per-kernel activation telemetry into
    host floats (the batch's logits are already computed, so pulling these
    scalars costs ONE small transfer, not a sync per field)."""
    diags = jax.device_get(diags)
    stored = sum(int(d["stored"]) for d in diags)
    capacity = sum(int(d["capacity"]) for d in diags)
    logical = sum(int(d["logical"]) for d in diags)
    overflows = sum(int(bool(d["overflow"])) for d in diags)
    return {
        "kernels": len(diags),
        "stored_blocks": stored,
        "capacity_blocks": capacity,
        "logical_blocks": logical,
        "overflows": overflows,
        "skipped_ratio": 1.0 - stored / max(1, logical),
    }


def batched_mm(engine: DynasparseEngine) -> gnn.MM:
    """The stacked-representation matmul the model zoo is applied against
    (the eager path: every kernel goes through ``engine.matmul``)."""
    return stacked_transport(gnn.engine_mm(engine))


class ServingEngine:
    """Async micro-batching front-end over one DynasparseEngine.

    One instance serves ONE model (name + params) over any number of
    registered graphs; the plan cache is the process-wide
    :func:`get_shared_cache` unless an engine/cache is supplied, so
    independent ServingEngines still share packed adjacencies.
    """

    def __init__(
        self,
        model: str,
        params: dict,
        engine: DynasparseEngine | None = None,
        *,
        config: ServingConfig = ServingConfig(),
        cache: SharedPlanCache | None = None,
    ):
        if model not in gnn.MODELS:
            raise ValueError(f"unknown model {model!r} (have {gnn.MODELS})")
        self.model = model
        self.params = params
        self.config = config
        self.faults = config.faults
        if engine is None:
            shared = cache if cache is not None else get_shared_cache()
            if config.n_devices is not None:
                from repro.launch.mesh import make_data_mesh
                # mesh serving implies the literal batched engine — the
                # sharded path is a compiled-dispatch route; a non-literal
                # mesh engine would silently fall back to single-device
                # eager execution
                engine = DynasparseEngine(
                    cache=shared, mesh=make_data_mesh(config.n_devices),
                    literal=True, batched=True,
                    operand_sharding=config.operand_sharding,
                    faults=config.faults)
            else:
                # `is None`, not `or`: an empty PlanCache is falsy (__len__)
                engine = DynasparseEngine(cache=shared, faults=config.faults)
        elif config.n_devices is not None and (
                engine.n_devices != config.n_devices):
            raise ValueError(
                f"ServingConfig.n_devices={config.n_devices} conflicts with "
                f"the supplied engine's mesh ({engine.n_devices} device(s)); "
                f"pass one or the other")
        # the sketch policy is applied around each dispatch, never left on a
        # caller-supplied engine (no hidden mutation outliving the serve)
        self.engine = engine
        if config.faults is not None:
            # chaos runs own their engine/cache: thread the injector through
            # so the instrumented plan/lower/pack/execute/snapshot sites fire
            self.engine.faults = config.faults
            if isinstance(self.engine.cache, SharedPlanCache):
                self.engine.cache.faults = config.faults
        self.stats = ServingStats()
        # RequestStats may be appended from the event loop (deadline expiry)
        # and the dispatch worker (batch completion) — same request, two
        # threads.  The lock plus _Request.recorded makes recording
        # exactly-once.
        self._stats_lock = threading.RLock()
        self._graphs: dict[str, SparseCOO] = {}
        self._queues: dict[str, collections.deque[_Request]] = {}
        self._draining: set[str] = set()
        # drift-churn circuit breakers, one per graph:
        # {events deque[monotonic], open_until, trips}
        self._breakers: dict[str, dict] = {}
        # dispatch-worker liveness/straggler surface: every micro-batch
        # heartbeats with its step time; dispatch_stats()["health"] exposes
        # the snapshot (distributed/fault.py doubles as the in-process
        # worker monitor)
        self._monitor = FaultMonitor(["dispatch-0"], timeout=60.0)
        # compiled whole-model programs, one per (graph, stacked shape,
        # dtype) — with pad_to_max_batch that is ONE program per graph
        self._compiled: dict[tuple, gnn.CompiledModel] = {}
        self._next_id = 0
        # ONE dispatch worker: micro-batches compute off the event loop (the
        # loop keeps coalescing the next burst), serialized so the shared
        # DynasparseEngine's report/sketch state is never touched twice at
        # once.
        self._dispatch_pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serving-dispatch")

    def dispatch_stats(self) -> dict:
        """Compiled-path observability: the plan/dispatch/trace counters of
        the underlying cache plus this engine's compiled-program registry
        (the dispatch benchmark's acceptance surface)."""
        s = self.engine.cache.stats
        st = self.stats
        n_act = len(st.activation_batches)
        return {
            "plans": self.engine.cache.plan_count(),
            "n_devices": self.engine.n_devices,
            "sharded_dispatches": self.engine.cache.sharded_count(),
            "operand_sharding": getattr(self.engine, "operand_sharding",
                                        "replicate"),
            # per-device dense-operand memory accounting of the sharded
            # dispatches (owned / halo / replicated-fallback bytes)
            "operand_bytes": self.engine.cache.sharded_operand_bytes(),
            "dispatch_builds": s.dispatch_builds,
            "dispatch_hits": s.dispatch_hits,
            "act_builds": s.act_builds,
            "act_hits": s.act_hits,
            "calib_builds": s.calib_builds,
            "calib_hits": s.calib_hits,
            "trace_builds": s.trace_builds,
            "trace_cache_hits": s.trace_cache_hits,
            "replans": s.replans,
            "compiled_models": len(self._compiled),
            "compiled_batches": st.compiled_batches,
            # sparse-activation route telemetry (running aggregates)
            "act_kernels_last": st.act_kernels_last,
            "act_overflows": st.act_overflows,
            "act_skipped_ratio_mean": (st.act_skipped_sum / n_act
                                       if n_act else 0.0),
            # degraded-mode telemetry + snapshot robustness
            "degraded_batches": st.degraded_batches,
            "bisections": st.bisections,
            "retries": st.retries,
            "quarantined": st.quarantined,
            "breaker_trips": st.breaker_trips,
            "deadline_expired": st.deadline_expired,
            "snapshot_errors": s.snapshot_errors,
            # dispatch-worker heartbeat/straggler view (FaultMonitor)
            "health": self._monitor.snapshot(),
        }

    def close(self) -> None:
        """Shut down the dispatch worker thread.  Call when retiring the
        engine (or use it as a context manager); long-lived processes that
        build engines per model/tenant would otherwise accumulate idle
        threads.  Idempotent; in-flight batches finish first."""
        self._dispatch_pool.shutdown(wait=True)

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- graphs
    def register_graph(self, graph_id: str, adj: SparseCOO) -> GraphKey:
        """Make ``graph_id`` servable.  Returns the content key; when the
        engine's cache is a SharedPlanCache the key is also recorded in its
        registry (persistence manifest / observability)."""
        if self._graphs.get(graph_id) is not adj:
            # a re-registered id may carry a DIFFERENT graph: compiled
            # whole-model programs bake the old adjacency's descriptors in,
            # and the input-density drift check cannot see an adjacency
            # swap — drop them so the next batch recompiles against adj
            for k in [k for k in self._compiled if k[0] == graph_id]:
                del self._compiled[k]
        self._graphs[graph_id] = adj
        self._queues.setdefault(graph_id, collections.deque())
        if isinstance(self.engine.cache, SharedPlanCache):
            return self.engine.cache.register_graph(graph_id, adj)
        return GraphKey.of(adj)

    # ------------------------------------------------------------ requests
    async def infer(self, graph_id: str, features) -> jnp.ndarray:
        """Submit one request and await its logits.  Concurrent callers on
        the same graph are coalesced into one micro-batch.

        With ``config.request_timeout`` set, a request that is still
        unresolved at the deadline raises :class:`DeadlineExceeded` and is
        recorded with a structured ``RequestStats.error`` — a straggling or
        wedged batch fails the caller fast instead of hanging ``serve()``.
        """
        if graph_id not in self._graphs:
            raise KeyError(f"graph {graph_id!r} is not registered")
        loop = asyncio.get_running_loop()
        q = self._queues[graph_id]
        stats = RequestStats(request_id=self._next_id, graph_id=graph_id,
                             queue_depth=len(q))
        self._next_id += 1
        req = _Request(features=jnp.asarray(features),
                       future=loop.create_future(), stats=stats,
                       t_enqueue=time.perf_counter())
        q.append(req)
        if graph_id not in self._draining:
            self._draining.add(graph_id)
            asyncio.ensure_future(self._drain(graph_id))
        timeout = self.config.request_timeout
        if timeout is None:
            return await req.future
        try:
            # wait_for cancels the future on expiry; _resolve's done() guard
            # makes a late worker-side resolution a harmless no-op
            return await asyncio.wait_for(req.future, timeout)
        except asyncio.TimeoutError:
            req.abandoned = True
            now = time.perf_counter()
            exc = DeadlineExceeded(
                f"request {stats.request_id} on graph {graph_id!r} missed "
                f"its {timeout}s deadline")
            with self._stats_lock:
                self.stats.deadline_expired += 1
            self._record_request(req, t0=now, t1=now,
                                 batch_size=req.stats.batch_size,
                                 error=f"{type(exc).__name__}: {exc}")
            raise exc from None

    async def _drain(self, graph_id: str) -> None:
        """Per-graph dispatcher: opened by the first request of a burst,
        closes when the queue runs dry.  The dry-check and the ``_draining``
        hand-back happen on the loop without an await between them, so a
        queue can never strand a request.  The compute itself is handed to
        the dispatch worker thread — the loop stays free to accept and
        coalesce the next burst while a batch executes."""
        loop = asyncio.get_running_loop()
        q = self._queues[graph_id]
        try:
            while q:
                if (len(q) < self.config.max_batch
                        and self.config.max_delay_s > 0):
                    await asyncio.sleep(self.config.max_delay_s)
                else:
                    await asyncio.sleep(0)   # let same-tick submitters land
                batch = [q.popleft()
                         for _ in range(min(len(q), self.config.max_batch))]
                # deadline-abandoned requests are already recorded/failed —
                # don't spend batch slots (or fault probes) on them
                batch = [r for r in batch if not r.abandoned]
                if batch:
                    try:
                        await loop.run_in_executor(
                            self._dispatch_pool, self._dispatch,
                            graph_id, batch)
                    except Exception as exc:
                        # anything _dispatch's own handling didn't catch
                        # (errors before its try block, a shut-down
                        # executor, ...) must still fail the popped batch's
                        # futures — stranding them deadlocks serve()
                        self._fail_batch(batch, time.perf_counter(), exc)
        finally:
            self._draining.discard(graph_id)

    @staticmethod
    def _resolve(fut: asyncio.Future, *, result=None, exc=None) -> None:
        """Resolve a future from any thread.  ``_dispatch`` runs on the
        worker executor, where ``Future.set_result`` is not thread-safe —
        hand the resolution to the future's own loop in that case."""
        def _set() -> None:
            if fut.done():
                return
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(result)

        loop = fut.get_loop()
        try:
            running = asyncio.get_running_loop()
        except RuntimeError:
            running = None
        if running is loop:
            _set()
        else:
            loop.call_soon_threadsafe(_set)

    def _record_request(self, r: _Request, *, t0: float, t1: float,
                        batch_size: int, report=None,
                        error: str | None = None) -> bool:
        """Append one request's stats exactly once (loop-side deadline
        expiry and worker-side batch completion may race to record the same
        request).  Returns False when someone else already recorded it."""
        with self._stats_lock:
            if r.recorded:
                return False
            r.recorded = True
            r.stats.batch_size = batch_size
            r.stats.t_queue = t0 - r.t_enqueue
            r.stats.t_execute = t1 - t0
            r.stats.latency = t1 - r.t_enqueue
            r.stats.report = report
            r.stats.error = error
            self.stats.requests.append(r.stats)
            return True

    def _fail_batch(self, batch: list[_Request], t0: float,
                    exc: Exception) -> None:
        """Fail every request of a batch AND record it: failed traffic must
        show up in ``requests``/``mean_batch_size`` (with ``error`` set),
        not silently undercount the stats."""
        t1 = time.perf_counter()
        with self._stats_lock:
            self.stats.batches += 1
        # record EVERY request before resolving ANY future: gather() raises
        # on the first exception, so a caller can observe stats the moment
        # one future fails — interleaving would undercount the batch
        self._monitor.heartbeat("dispatch-0", step_time=t1 - t0)
        for r in batch:
            self._record_request(r, t0=t0, t1=t1, batch_size=len(batch),
                                 error=f"{type(exc).__name__}: {exc}")
        for r in batch:
            self._resolve(r.future, exc=exc)

    # ------------------------------------------------------ circuit breaker
    def _breaker(self, graph_id: str) -> dict:
        return self._breakers.setdefault(
            graph_id,
            {"events": collections.deque(), "open_until": 0.0, "trips": 0})

    def _breaker_open(self, graph_id: str) -> bool:
        b = self._breakers.get(graph_id)
        return b is not None and time.monotonic() < b["open_until"]

    def _breaker_event(self, graph_id: str) -> bool:
        """Record one compiled-program invalidation event.  Returns True
        when this event TRIPS the breaker: the caller then pins the
        last-good program through the cooldown instead of invalidating —
        bounding drift→replan→recompile churn when inputs oscillate around
        the drift threshold."""
        b = self._breaker(graph_id)
        now = time.monotonic()
        ev = b["events"]
        ev.append(now)
        while ev and now - ev[0] > self.config.breaker_window_s:
            ev.popleft()
        if len(ev) >= self.config.breaker_threshold:
            b["open_until"] = now + self.config.breaker_cooldown_s
            b["trips"] += 1
            ev.clear()
            with self._stats_lock:
                self.stats.breaker_trips += 1
            return True
        return False

    # ------------------------------------------------- degradation ladder
    def _dispatch(self, graph_id: str, batch: list[_Request]) -> None:
        """Worker-thread entry for one micro-batch: run the degradation
        ladder.  Per-step times are heartbeated from the resolution sites
        (``_execute_batch`` / ``_fail_batch``) BEFORE any future resolves —
        the ``dispatch_stats()["health"]`` surface must show a batch by the
        time its caller unblocks.  The epilogue heartbeat here is
        liveness-only (no step time) so steps aren't double-counted."""
        try:
            batch = [r for r in batch
                     if not (r.abandoned or r.future.done())]
            if batch:
                self._serve_batch(graph_id, batch)
        finally:
            self._monitor.heartbeat("dispatch-0")

    def _serve_batch(self, graph_id: str, batch: list[_Request],
                     attempt: int = 0) -> None:
        """One rung of the degradation ladder.

        Try the batch as a unit (``_execute_batch`` internally degrades a
        failed compiled program to the eager path first).  If the whole
        attempt still fails, bisect: each half retries independently, so a
        poison request descends the ladder alone while its neighbours are
        re-served bit-identically (pad_to_max_batch keeps the kernel
        geometry — and therefore each request's column block — independent
        of batch composition).  A request failing alone gets
        ``max_retries`` backoff retries (transient faults recover), then is
        quarantined: ITS future carries the error, nobody else's.
        """
        t0 = time.perf_counter()
        try:
            if self.faults is not None:
                self.faults.probe("dispatch", detail=graph_id)
                for r in batch:
                    # ';' terminates the id so match="req:1;" can never
                    # poison request 11 as well
                    self.faults.probe(
                        "request", detail=f"req:{r.stats.request_id};")
            self._execute_batch(graph_id, batch, t0)
            return
        except Exception as exc:
            err = exc
        if len(batch) > 1:
            with self._stats_lock:
                self.stats.bisections += 1
            mid = len(batch) // 2
            self._serve_batch(graph_id, batch[:mid])
            self._serve_batch(graph_id, batch[mid:])
            return
        if attempt < self.config.max_retries:
            with self._stats_lock:
                self.stats.retries += 1
            if self.config.retry_backoff_s > 0:
                time.sleep(self.config.retry_backoff_s * (2 ** attempt))
            self._serve_batch(graph_id, batch, attempt=attempt + 1)
            return
        with self._stats_lock:
            self.stats.quarantined += 1
        self._fail_batch(batch, t0, err)

    def _execute_batch(self, graph_id: str, batch: list[_Request],
                       t0: float) -> None:
        """Serve one micro-batch: stack → pad → one engine pass → split.

        Runs on the single dispatch worker thread; futures are resolved
        back on their loop.  Raises on failure — the ladder above decides
        whether to bisect, retry or quarantine.  One degradation happens
        HERE: a compiled program that fails mid-call falls back to the
        eager batched path for this batch (``degraded_batches``), keeping
        the program for the next batch (a transient executor fault should
        not force a recompile).
        """
        adj = self._graphs[graph_id]
        k = len(batch)
        widths = [r.features.shape[1] for r in batch]
        if len(set(widths)) != 1:   # model zoo fixes the fan-in per model
            raise ValueError(f"micro-batch mixes feature widths {widths}")
        h = (batch[0].features if k == 1
             else jnp.concatenate([r.features for r in batch], axis=1))
        kp = k
        if self.config.pad_to_max_batch and k < self.config.max_batch:
            # single-plan serving: pad the stacked width to max_batch so the
            # engine sees one kernel geometry per graph across all traffic.
            # The padding REPLICATES the batch's own feature columns
            # (cycling through its requests) rather than zero-filling: zero
            # columns would register as density drift against full batches
            # and thrash the replanner, and would bias the first plan's
            # column densities.  Each request's output block depends only on
            # its own columns, so replication leaves results exact.
            kp = self.config.max_batch
            h = jnp.concatenate(
                [h] + [batch[i % k].features for i in range(kp - k)], axis=1)

        saved = (self.engine.drift_threshold, self.engine.sketch_rows)
        compiled = False
        degraded = False
        try:
            self.config.sketch.apply(self.engine)
            breaker_open = self._breaker_open(graph_id)
            if breaker_open:
                # cooldown: pin whatever is compiled, suppress eager replans
                self.engine.drift_threshold = None
            cm_key = (graph_id, tuple(h.shape), str(h.dtype))
            cm = (self._compiled.get(cm_key)
                  if self.config.compile_models else None)
            thr = self.config.sketch.threshold
            if (cm is not None and thr is not None and not breaker_open
                    and cm.drifted(
                        h, thr, max_rows=self.config.sketch.max_rows,
                        eps=self.engine.eps)):
                if self._breaker_event(graph_id):
                    # churn breaker tripped: serve this (and the cooldown's)
                    # traffic on the last-good program instead of entering
                    # another replan→recompile cycle
                    self.engine.drift_threshold = None
                else:
                    # stale compiled program: the eager re-run below replans
                    # drifted kernels, then a fresh program is compiled
                    self._compiled.pop(cm_key, None)
                    with self._stats_lock:
                        self.stats.compile_invalidations += 1
                    cm = None
            if cm is not None:
                try:
                    logits = cm(h)
                    report = cm.fresh_report()
                    compiled = True
                    if cm.last_activation:
                        with self._stats_lock:
                            self.stats.record_activation(
                                _activation_summary(cm.last_activation))
                except Exception:
                    # degraded mode: compiled call failed → serve THIS batch
                    # on the eager batched path (program kept — see above)
                    degraded = True
                    self.engine.reset()
                    logits = gnn.APPLY[self.model](
                        batched_mm(self.engine), adj, h, self.params)
                    report = self.engine.report
            else:
                self.engine.reset()
                if self.config.compile_models:
                    logits, built = gnn.compile_model(
                        self.model, self.engine, adj, h, self.params,
                        transport=stacked_transport,
                        activation_skip=self.config.activation_skip,
                        activation_slack=self.config.activation_slack,
                        activation_per_stripe=(
                            self.config.activation_per_stripe))
                    if built is not None:
                        self._compiled[cm_key] = built
                        while len(self._compiled) > self.config.max_compiled:
                            self._compiled.pop(next(iter(self._compiled)))
                else:
                    logits = gnn.APPLY[self.model](batched_mm(self.engine),
                                                   adj, h, self.params)
                report = self.engine.report
        finally:
            self.engine.drift_threshold, self.engine.sketch_rows = saved
        t1 = time.perf_counter()
        out_w = logits.shape[1] // kp
        with self._stats_lock:
            self.stats.batches += 1
            self.stats.compiled_batches += int(compiled)
            self.stats.degraded_batches += int(degraded)
            self.stats.batch_reports.append(report)
        # heartbeat BEFORE resolving any future: serve() returns the moment
        # the last future resolves, and dispatch_stats()["health"] must
        # already show this batch's step by then (racing the worker's
        # epilogue against the caller reads as a missed heartbeat)
        self._monitor.heartbeat("dispatch-0", step_time=t1 - t0)
        share = report.attributed(k)
        for idx, r in enumerate(batch):
            z = logits[:, idx * out_w:(idx + 1) * out_w]
            self._record_request(r, t0=t0, t1=t1, batch_size=k, report=share)
            self._resolve(r.future, result=z)

    # ------------------------------------------------------ sync interface
    def serve(self, requests: Iterable[tuple[str, object]],
              *, arrival_delay_s: float = 0.0,
              return_exceptions: bool = False) -> list:
        """Blocking convenience: submit ``(graph_id, features)`` pairs as
        concurrent requests, return logits in submission order.  Requests
        submitted in one call coalesce exactly as live traffic would.

        ``return_exceptions=True`` resolves EVERY slot — a failed or
        deadline-expired request yields its exception object in place of
        logits instead of aborting the gather (chaos traffic: no submission
        is ever left unanswered).

        Safe to call with or without a running event loop: plain scripts go
        through ``asyncio.run``; when the calling thread already runs a loop
        (notebooks, async servers), the burst is driven on a dedicated
        thread's fresh loop instead — ``asyncio.run`` would raise
        ``RuntimeError`` there."""
        reqs = list(requests)

        async def _run() -> Sequence[jnp.ndarray]:
            tasks = []
            for gid, h in reqs:
                tasks.append(asyncio.ensure_future(self.infer(gid, h)))
                if arrival_delay_s:
                    await asyncio.sleep(arrival_delay_s)
            return await asyncio.gather(*tasks,
                                        return_exceptions=return_exceptions)

        try:
            asyncio.get_running_loop()
        except RuntimeError:
            return list(asyncio.run(_run()))
        with concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="serving-loop") as pool:
            return list(pool.submit(asyncio.run, _run()).result())
