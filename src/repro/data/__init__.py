from repro.data.graphs import DATASETS, Graph, load_graph

__all__ = ["DATASETS", "Graph", "load_graph"]
