"""Graph datasets — synthetic stand-ins matching the paper's Table IV.

This container is offline, so CiteSeer/Cora/PubMed/Flickr/NELL/Reddit cannot
be downloaded.  We generate graphs with the SAME vertex count, edge count,
feature dimension, class count, adjacency density and input-feature density
as Table IV, with a hub-skewed (Zipf-like) degree distribution so that
per-stripe densities vary the way real scale-free graphs do (which is what
exercises the paper's dynamic per-task decisions).  All generators are
deterministic per dataset name.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core.primitives import SparseCOO


@dataclasses.dataclass(frozen=True)
class DatasetStats:
    name: str
    vertices: int
    edges: int
    features: int
    classes: int
    density_a: float          # Table IV "Density of A" (self-check only)
    density_h: float          # Table IV "Density of input H"
    hidden: int               # paper §IV-B: 16 for CO/CI/PU else 128


# Table IV, verbatim (Reddit edge count "11x10^7").
DATASETS: dict[str, DatasetStats] = {
    "CO": DatasetStats("CO", 2708, 5429, 2708, 7, 0.0014, 0.0127, 16),
    "CI": DatasetStats("CI", 3327, 4732, 3703, 6, 0.0008, 0.0085, 16),
    "PU": DatasetStats("PU", 19717, 44338, 500, 3, 0.0002, 0.10, 16),
    "FL": DatasetStats("FL", 89250, 899756, 500, 7, 0.0001, 0.46, 128),
    "NE": DatasetStats("NE", 65755, 251550, 61278, 186, 0.000058, 0.0001, 128),
    "RE": DatasetStats("RE", 232965, 110_000_000, 602, 41, 0.0021, 1.0, 128),
}


@dataclasses.dataclass
class Graph:
    stats: DatasetStats
    adj: SparseCOO            # row-normalized adjacency with self-loops
    features: jnp.ndarray | SparseCOO   # dense H, or COO when H is ultra-sparse

    @property
    def features_dense(self) -> jnp.ndarray:
        if isinstance(self.features, SparseCOO):
            return jnp.asarray(self.features.todense())
        return self.features

    @property
    def feature_density(self) -> float:
        if isinstance(self.features, SparseCOO):
            return self.features.density
        h = np.asarray(self.features)
        return float((h != 0).mean())


def _zipf_targets(rng: np.random.Generator, n: int, size: int,
                  skew: float = 2.0) -> np.ndarray:
    """Hub-skewed endpoint sampling: P(v) ∝ rank^-ish via u^skew mapping."""
    u = rng.uniform(size=size)
    return np.minimum((n * u ** skew).astype(np.int64), n - 1)


def _gen_edges(rng: np.random.Generator, n: int, e: int) -> tuple[np.ndarray, np.ndarray]:
    src = rng.integers(0, n, size=e, dtype=np.int64)
    dst = _zipf_targets(rng, n, e)
    return src, dst


def _normalize_adj(n: int, src: np.ndarray, dst: np.ndarray) -> SparseCOO:
    """Â = D^{-1/2} (A + I) D^{-1/2} (GCN renormalization trick)."""
    rows = np.concatenate([src, np.arange(n, dtype=np.int64)])
    cols = np.concatenate([dst, np.arange(n, dtype=np.int64)])
    deg = np.bincount(rows, minlength=n).astype(np.float32)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    vals = dinv[rows] * dinv[cols]
    order = np.argsort(rows, kind="stable")
    return SparseCOO(
        (n, n),
        jnp.asarray(rows[order], jnp.int32),
        jnp.asarray(cols[order], jnp.int32),
        jnp.asarray(vals[order].astype(np.float32)),
        tag="adjacency",
    )


def _gen_features(rng: np.random.Generator, stats: DatasetStats,
                  sparse_threshold: float = 0.01):
    """Bag-of-words-like binary features at the Table IV density.  Ultra-
    sparse feature matrices (NELL: 0.01%) stay in COO to avoid a 65k x 61k
    dense allocation."""
    n, f, d = stats.vertices, stats.features, stats.density_h
    if d >= 1.0:
        return jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    nnz = max(1, int(round(n * f * d)))
    if d < sparse_threshold and n * f > 50_000_000:
        rows = rng.integers(0, n, size=nnz, dtype=np.int64)
        cols = rng.integers(0, f, size=nnz, dtype=np.int64)
        order = np.argsort(rows, kind="stable")
        return SparseCOO((n, f), jnp.asarray(rows[order], jnp.int32),
                         jnp.asarray(cols[order], jnp.int32),
                         jnp.asarray(np.ones(nnz, np.float32)),
                         tag="features")
    h = np.zeros((n, f), np.float32)
    idx = rng.choice(n * f, size=nnz, replace=False)
    h.flat[idx] = 1.0
    return jnp.asarray(h)


@functools.lru_cache(maxsize=8)
def load_graph(name: str, scale: float = 1.0) -> Graph:
    """Build the synthetic dataset.  ``scale < 1`` shrinks vertices/edges
    proportionally (density preserved) for CPU-budget functional runs."""
    stats = DATASETS[name]
    if scale != 1.0:
        stats = dataclasses.replace(
            stats,
            vertices=max(64, int(stats.vertices * scale)),
            edges=max(128, int(stats.edges * scale)),
            features=max(16, int(stats.features * min(1.0, scale * 4))),
        )
    # stable across processes (builtin hash() is salted)
    seed = zlib.crc32(f"{name}:{scale}".encode()) % (2**31)
    rng = np.random.default_rng(seed)
    src, dst = _gen_edges(rng, stats.vertices, stats.edges)
    adj = _normalize_adj(stats.vertices, src, dst)
    feats = _gen_features(rng, stats)
    return Graph(stats=stats, adj=adj, features=feats)
