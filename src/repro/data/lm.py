"""Synthetic LM token pipeline with host-side prefetch.

Offline container ⇒ tokens are synthesized (Zipf-distributed ids, fixed
seed per shard).  The pipeline shape matches a production loader: per-host
sharded streams, a background prefetch thread keeping ``depth`` batches
ready, and deterministic resume via (shard, step) addressing — the data
side of checkpoint-restart.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenPipeline:
    def __init__(self, *, vocab: int, batch: int, seq_len: int,
                 shard: int = 0, n_shards: int = 1, seed: int = 1234,
                 depth: int = 2, start_step: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.shard = shard
        self.n_shards = n_shards
        self.seed = seed
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make_batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed, self.shard, step))  # resume-deterministic
        # Zipf-ish marginal over ids (realistic softmax target distribution)
        u = rng.uniform(size=(self.batch, self.seq_len))
        toks = np.minimum((self.vocab * u ** 3).astype(np.int32),
                          self.vocab - 1)
        return {"tokens": toks}

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            b = self._make_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        step, b = self._q.get()
        self.step = step + 1
        return b

    def close(self) -> None:
        self._stop.set()
