"""Model registry: build (init / loss / forward / decode) bundles from a
``ModelConfig`` and produce dry-run input specs for every shape cell."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, SUBQUADRATIC, ModelConfig, ShapeConfig
from repro.models import encdec as encdec_lib
from repro.models import lm as lm_lib


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    abstract_params: Callable[[], Any]
    loss: Callable[..., jax.Array]            # (params, batch) -> scalar
    forward: Callable[..., jax.Array]         # (params, batch) -> logits
    decode_step: Callable[..., tuple] | None  # (params, cache, tok, pos)
    abstract_cache: Callable[..., Any] | None # (batch, max_len) -> specs
    init_cache: Callable[..., Any] | None


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.n_enc_layers:
        return ModelBundle(
            cfg=cfg,
            init=lambda key: encdec_lib.init_encdec(key, cfg),
            abstract_params=lambda: encdec_lib.abstract_params(cfg),
            loss=lambda p, b: encdec_lib.lm_loss(p, b, cfg),
            forward=lambda p, b: encdec_lib.forward(p, b, cfg),
            decode_step=lambda p, c, t, pos: encdec_lib.decode_step(
                p, c, t, pos, cfg),
            abstract_cache=lambda batch, max_len: encdec_lib.abstract_cache(
                cfg, batch, max_len, max_tgt=max(1024, max_len // 32)),
            init_cache=lambda batch, max_len: encdec_lib.init_cache(
                cfg, batch, max_len, max_tgt=max(1024, max_len // 32)),
        )
    return ModelBundle(
        cfg=cfg,
        init=lambda key: lm_lib.init_lm(key, cfg),
        abstract_params=lambda: lm_lib.abstract_params(cfg),
        loss=lambda p, b: lm_lib.lm_loss(p, b, cfg),
        forward=lambda p, b: lm_lib.forward(p, b, cfg),
        decode_step=lambda p, c, t, pos: lm_lib.decode_step(p, c, t, pos, cfg),
        abstract_cache=lambda batch, max_len: lm_lib.abstract_cache(
            cfg, batch, max_len),
        init_cache=lambda batch, max_len: lm_lib.init_cache(
            cfg, batch, max_len),
    )


# ---------------------------------------------------------------- specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    train/prefill: a token batch (plus stub frontend embeddings for
    audio/vlm archs, plus M-RoPE positions).  decode: one token per
    sequence + position scalar (the KV cache is built separately via
    ``abstract_cache`` and passed as donated state).
    """
    B, L = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        if cfg.n_enc_layers:
            # enc-dec: frames are the long (audio) side; text targets short
            lt = max(128, min(1024, L // 32))
            return {"frames": _sds((B, L, cfg.d_model), dt),
                    "tokens": _sds((B, lt), jnp.int32)}
        batch: dict = {}
        if cfg.frontend_prefix > 0:
            lp = int(L * cfg.frontend_prefix)
            batch["embeds"] = _sds((B, lp, cfg.d_model), dt)
            batch["tokens"] = _sds((B, L - lp), jnp.int32)
            if cfg.mrope_sections:
                batch["positions"] = _sds((B, L, 3), jnp.int32)
        else:
            batch["tokens"] = _sds((B, L), jnp.int32)
        return batch

    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((B, 1), jnp.int32),
            "pos": _sds((), jnp.int32)}


def cell_is_runnable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention; encoder-only archs have no
    decode step (none assigned).  Returns (runnable, reason-if-skipped)."""
    if shape_name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, ("full O(L²) attention at 524k context — skipped by "
                       "design (DESIGN.md §4); run for SSM/hybrid archs only")
    return True, ""
