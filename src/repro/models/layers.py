"""Shared transformer building blocks (pure-functional JAX).

Everything here is written for the TPU target: attention is a both-chunked
online-softmax (flash-style) double ``lax.scan`` so the score matrix never
materializes (O(qc·kc) VMEM working set per step instead of O(L²) HBM), GQA
is computed in grouped form without repeating KV heads, and all contractions
accumulate in f32.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sections: tuple[int, ...] | None = None) -> jax.Array:
    """Rotary embedding.  ``x``: [..., L, H, Dh]; ``positions``: [B, L]
    (classic) or [B, L, 3] (M-RoPE; ``sections`` gives the per-stream split
    of Dh/2 frequency slots, Qwen2-VL style: temporal/height/width)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)      # (Dh/2,)
    if sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # [B,L,Dh/2]
    else:
        assert positions.ndim == 3 and positions.shape[-1] == len(sections)
        parts = []
        off = 0
        for i, sec in enumerate(sections):
            parts.append(positions[..., i:i + 1].astype(jnp.float32)
                         * freqs[off:off + sec])
            off += sec
        assert off == dh // 2, (sections, dh)
        angles = jnp.concatenate(parts, axis=-1)                   # [B,L,Dh/2]
    cos = jnp.cos(angles)[:, :, None, :]                           # [B,L,1,Dh/2]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
class _FlashCarry(NamedTuple):
    m: jax.Array    # running max      [B, Hkv, G, qc]
    l: jax.Array    # running denom    [B, Hkv, G, qc]
    acc: jax.Array  # running numer    [B, Hkv, G, qc, Dh]


def flash_attention(
    q: jax.Array,               # [B, Lq, Hq, Dh]
    k: jax.Array,               # [B, Lk, Hkv, Dh]
    v: jax.Array,               # [B, Lk, Hkv, Dh]
    *,
    causal: bool = True,
    window: int | None = None,  # local attention: kv within (qpos-window, qpos]
    q_offset: int = 0,          # global position of q[0] (decode/prefill tail)
    q_chunk: int = 512,
    kv_chunk: int = 512,
    kv_len_mask: int | None = None,   # only the first N kv positions are valid
    causal_skip: bool = False,        # unroll q blocks; visit only kv <= q
) -> jax.Array:
    """Both-chunked online-softmax attention with grouped (GQA) heads.

    Memory per step is O(q_chunk x kv_chunk) — the TPU VMEM-resident flash
    pattern — so 32k prefill never materializes an L² score matrix.

    ``causal_skip`` trades HLO size for FLOPs: the outer q loop is unrolled
    in Python so each q block's inner scan covers only the causally-visible
    kv blocks — the upper triangle is never computed (2x causal-FLOP
    reduction; §Perf hillclimb).
    """
    B, Lq, Hq, Dh = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(Dh)

    qc = min(q_chunk, Lq)
    kc = min(kv_chunk, Lk)
    # pad to chunk multiples
    nq, nk = -(-Lq // qc), -(-Lk // kc)
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    valid_k = kv_len_mask if kv_len_mask is not None else Lk

    # [nq, B, qc, Hkv, G, Dh]
    qb = q.reshape(B, nq, qc, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kc, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kc, Hkv, Dh).transpose(1, 0, 2, 3, 4)

    q_pos_base = jnp.arange(qc) + q_offset
    k_pos_base = jnp.arange(kc)

    def q_block(carry, iq_and_qblk):
        iq, qblk = iq_and_qblk            # qblk [B, qc, Hkv, G, Dh]
        q_pos = q_pos_base + iq * qc      # [qc]

        def kv_block(inner, ik_and_kv):
            ik, kblk, vblk = ik_and_kv
            k_pos = k_pos_base + ik * kc  # [kc]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            mask = (k_pos[None, :] < valid_k)
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            mask = mask[None, None, None]                  # [1,1,1,qc,kc]
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(inner.m, s.max(axis=-1))
            # masked-row safe: p forced to 0 where invalid
            p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(inner.m - m_new)
            l_new = inner.l * corr + p.sum(axis=-1)
            acc_new = (inner.acc * corr[..., None]
                       + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                    vblk.astype(jnp.float32)))
            return _FlashCarry(m_new, l_new, acc_new), None

        init = _FlashCarry(
            m=jnp.full((B, Hkv, G, qc), -1e30, jnp.float32),
            l=jnp.zeros((B, Hkv, G, qc), jnp.float32),
            acc=jnp.zeros((B, Hkv, G, qc, Dh), jnp.float32),
        )
        n_vis = nk if not isinstance(iq, int) else min(
            nk, (iq * qc + qc + kc - 1) // kc) if causal else nk
        final, _ = jax.lax.scan(kv_block, init,
                                (jnp.arange(n_vis), kb[:n_vis], vb[:n_vis]))
        out = final.acc / jnp.maximum(final.l, 1e-20)[..., None]
        return carry, out.transpose(0, 3, 1, 2, 4)  # [B, qc, Hkv, G, Dh]

    if causal_skip and causal:
        # Python-unrolled outer loop: static iq ⇒ statically-bounded inner
        # scan lengths — the upper triangle never lowers to HLO at all
        blocks = jnp.stack([q_block(None, (iq, qb[iq]))[1]
                            for iq in range(nq)])
    else:
        _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    # blocks: [nq, B, qc, Hkv, G, Dh] -> [B, Lq, Hq, Dh]
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, Hq, Dh)
    return out[:, :Lq].astype(q.dtype)


def decode_attention(
    q: jax.Array,           # [B, 1, Hq, Dh] — one new token
    k_cache: jax.Array,     # [B, Lmax, Hkv, Dh]
    v_cache: jax.Array,
    cur_len: jax.Array,     # scalar int: valid cache length INCLUDING new tok
    *,
    window: int | None = None,
) -> jax.Array:
    """Single-step attention over a (padded) KV cache."""
    B, Lmax, Hkv, Dh = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(Dh)
    qg = q.reshape(B, 1, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(Lmax)
    mask = pos[None] < cur_len
    if window is not None:
        mask = mask & (pos[None] > cur_len - 1 - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)


# ----------------------------------------------------------------------
# Flash attention with custom VJP (§Perf hillclimb: "flash_vjp").
#
# Differentiating the double-scan flash forward makes JAX save the f32
# probability block for EVERY (q, kv) block pair — a stacked
# [nq, nk, qc, kc] buffer per layer that dominates HBM traffic (26 TB/step
# on deepseek-v2-236b train_4k).  The flash backward instead recomputes p
# from the saved (q, k, v, out, lse) — residuals shrink to O(L) per head.
# ----------------------------------------------------------------------
def _flash_pieces(q, k, v, opts):
    """Shared fwd returning output AND logsumexp (for the custom bwd)."""
    causal, window, q_offset, qc, kc, valid_k, causal_skip = opts
    B, Lq, Hq, Dh = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(Dh)
    nq, nk = -(-Lq // qc), -(-Lk // kc)
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, qc, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(B, nk, kc, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, kc, Hkv, Dh).transpose(1, 0, 2, 3, 4)

    def mask_for(iq, ik):
        q_pos = jnp.arange(qc) + iq * qc + q_offset
        k_pos = jnp.arange(kc) + ik * kc
        m = (k_pos[None, :] < valid_k)
        if causal:
            m = m & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            m = m & (k_pos[None, :] > q_pos[:, None] - window)
        return m[None, None, None]

    def q_block(_, iq_qblk):
        iq, qblk = iq_qblk

        def kv_block(inner, ik_kv):
            ik, kblk, vblk = ik_kv
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            msk = mask_for(iq, ik)
            s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(inner.m, s.max(axis=-1))
            p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(inner.m - m_new)
            return _FlashCarry(
                m_new, inner.l * corr + p.sum(-1),
                inner.acc * corr[..., None]
                + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                             vblk.astype(jnp.float32))), None

        init = _FlashCarry(jnp.full((B, Hkv, G, qc), -1e30, jnp.float32),
                           jnp.zeros((B, Hkv, G, qc), jnp.float32),
                           jnp.zeros((B, Hkv, G, qc, Dh), jnp.float32))
        n_vis = (min(nk, (iq * qc + qc + kc - 1) // kc)
                 if (causal_skip and causal and isinstance(iq, int)) else nk)
        fin, _ = jax.lax.scan(kv_block, init,
                              (jnp.arange(n_vis), kb[:n_vis], vb[:n_vis]))
        out = fin.acc / jnp.maximum(fin.l, 1e-20)[..., None]
        lse = fin.m + jnp.log(jnp.maximum(fin.l, 1e-20))
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    if causal_skip and causal:
        outs, lses = zip(*[q_block(None, (iq, qb[iq]))[1] for iq in range(nq)])
        blocks, lse = jnp.stack(outs), jnp.stack(lses)
    else:
        _, (blocks, lse) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, Hq, Dh)
    return out[:, :Lq].astype(q.dtype), lse  # lse: [nq, B, Hkv, G, qc]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_core(q, k, v, opts):
    return _flash_pieces(q, k, v, opts)[0]


def _flash_core_fwd(q, k, v, opts):
    out, lse = _flash_pieces(q, k, v, opts)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(opts, res, g):
    causal, window, q_offset, qc, kc, valid_k, causal_skip = opts
    q, k, v, out, lse = res
    B, Lq, Hq, Dh = q.shape
    _, Lk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(Dh)
    nq, nk = -(-Lq // qc), -(-Lk // kc)
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    dop = jnp.pad(g.astype(jnp.float32),
                  ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    outp = jnp.pad(out.astype(jnp.float32),
                   ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, qc, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(B, nk, kc, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, kc, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    dob = dop.reshape(B, nq, qc, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    # delta = rowsum(do * out): [nq, B, Hkv, G, qc]
    delta = ((dop * outp).sum(-1).reshape(B, nq, qc, Hkv, G)
             .transpose(1, 0, 3, 4, 2))

    def mask_for(iq, ik):
        q_pos = jnp.arange(qc) + iq * qc + q_offset
        k_pos = jnp.arange(kc) + ik * kc
        m = (k_pos[None, :] < valid_k)
        if causal:
            m = m & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            m = m & (k_pos[None, :] > q_pos[:, None] - window)
        return m[None, None, None]

    def p_of(iq, ik, qblk, kblk, lse_blk):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        return jnp.where(mask_for(iq, ik), jnp.exp(s - lse_blk[..., None]),
                         0.0)

    # ---- dq pass: scan q blocks, inner scan kv blocks
    def dq_block(_, xs):
        iq, qblk, doblk, lse_blk, dlt = xs

        def inner(dq_acc, ik_kv):
            ik, kblk, vblk = ik_kv
            p = p_of(iq, ik, qblk, kblk, lse_blk)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doblk,
                            vblk.astype(jnp.float32))
            ds = p * (dp - dlt[..., None])
            return dq_acc + scale * jnp.einsum(
                "bhgqk,bkhd->bqhgd", ds, kblk.astype(jnp.float32)), None

        dq0 = jnp.zeros((B, qc, Hkv, G, Dh), jnp.float32)
        dq_blk, _ = jax.lax.scan(inner, dq0, (jnp.arange(nk), kb, vb))
        return None, dq_blk

    _, dq_blocks = jax.lax.scan(
        dq_block, None, (jnp.arange(nq), qb, dob, lse, delta))

    # ---- dk/dv pass: scan kv blocks, inner scan q blocks
    def dkv_block(_, xs):
        ik, kblk, vblk = xs

        def inner(acc, iq_xs):
            dk_acc, dv_acc = acc
            iq, qblk, doblk, lse_blk, dlt = iq_xs
            p = p_of(iq, ik, qblk, kblk, lse_blk)
            dv_acc = dv_acc + jnp.einsum("bhgqk,bqhgd->bkhd", p, doblk)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doblk,
                            vblk.astype(jnp.float32))
            ds = p * (dp - dlt[..., None])
            dk_acc = dk_acc + scale * jnp.einsum(
                "bhgqk,bqhgd->bkhd", ds, qblk.astype(jnp.float32))
            return (dk_acc, dv_acc), None

        z = (jnp.zeros((B, kc, Hkv, Dh), jnp.float32),
             jnp.zeros((B, kc, Hkv, Dh), jnp.float32))
        (dk_blk, dv_blk), _ = jax.lax.scan(
            inner, z, (jnp.arange(nq), qb, dob, lse, delta))
        return None, (dk_blk, dv_blk)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(
        dkv_block, None, (jnp.arange(nk), kb, vb))

    dq = dq_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(
        B, nq * qc, Hq, Dh)[:, :Lq].astype(q.dtype)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(
        B, nk * kc, Hkv, Dh)[:, :Lk].astype(k.dtype)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(
        B, nk * kc, Hkv, Dh)[:, :Lk].astype(v.dtype)
    return dq, dk, dv


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention_vjp(q, k, v, *, causal=True, window=None, q_offset=0,
                        q_chunk=512, kv_chunk=512, kv_len_mask=None,
                        causal_skip=False):
    """Flash attention with the recompute-based custom backward."""
    Lk = k.shape[1]
    opts = (causal, window, q_offset, min(q_chunk, q.shape[1]),
            min(kv_chunk, Lk), kv_len_mask if kv_len_mask is not None else Lk,
            causal_skip)
    return _flash_core(q, k, v, opts)


# --------------------------------------------------------------- MLPs
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u,
                      w_down.astype(x.dtype))


def geglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
          w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(g) * u,
                      w_down.astype(x.dtype))


# --------------------------------------------------------------- init utils
def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    s = np.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype) * s
