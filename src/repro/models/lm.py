"""Generic decoder-only LM stack covering the dense / MoE / MLA / hybrid /
SSM / VLM assigned architectures.

Layers are grouped into *cycles* (one pass over ``cfg.mixer_pattern``, e.g.
RecurrentGemma's (rglru, rglru, attn)); homogeneous cycles are stacked and
executed with ``lax.scan`` so the lowered HLO stays O(cycle) instead of
O(n_layers) — essential for compile times of 60-88-layer configs.  Remnant
layers (n_layers % cycle) are unrolled.  ``remat="full"`` wraps the scanned
body in ``jax.checkpoint`` (per-cycle activation recomputation).

Modality frontends are STUBS per the assignment: ``batch["embeds"]``
(precomputed frame/patch embeddings) is concatenated ahead of the token
embeddings; loss is only taken on token positions.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import ffn as ffn_lib
from repro.models import mixers as mix
from repro.models.layers import glorot, rms_norm


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ init
def _init_layer(key, mixer_type: str, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    init_mixer = mix.MIXERS[mixer_type][0]
    p = {"mixer": init_mixer(k1, cfg),
         "mixer_norm": jnp.ones((cfg.d_model,))}
    if cfg.ffn != "none":
        p["ffn"] = ffn_lib.init_ffn(k2, cfg)
        p["ffn_norm"] = jnp.ones((cfg.d_model,))
    return p


def _init_cycle(key, cfg: ModelConfig):
    ks = jax.random.split(key, len(cfg.mixer_pattern))
    return {f"layer{j}": _init_layer(ks[j], mt, cfg)
            for j, mt in enumerate(cfg.mixer_pattern)}


def init_lm(key, cfg: ModelConfig):
    n_cycles, n_tail = divmod(cfg.n_layers, cfg.cycle_len())
    ks = jax.random.split(key, 4 + n_tail)
    params: dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model))
        * 0.02,
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = glorot(ks[1], (cfg.d_model, cfg.padded_vocab))
    cycle_keys = jax.random.split(ks[2], n_cycles)
    params["cycles"] = jax.vmap(lambda k: _init_cycle(k, cfg))(cycle_keys)
    params["tail"] = [
        _init_layer(ks[4 + i], cfg.mixer_pattern[i], cfg)
        for i in range(n_tail)
    ]
    return params


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """Parameter pytree as ShapeDtypeStructs — no allocation (dry-run)."""
    return jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(seed), cfg))


# ------------------------------------------------------------------ forward
def _apply_layer(lp, mixer_type: str, x, positions, cfg: ModelConfig):
    train_fn = mix.MIXERS[mixer_type][1]
    h = rms_norm(x, lp["mixer_norm"], cfg.norm_eps)
    x = x + train_fn(lp["mixer"], h, positions, cfg)
    if cfg.ffn != "none":
        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + ffn_lib.apply_ffn(lp["ffn"], h, cfg)
    return x


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token embeddings, optionally prefixed by frontend stub embeddings."""
    dt = _dtype(cfg)
    x = params["embed"].astype(dt)[batch["tokens"]]
    if batch.get("embeds") is not None:
        x = jnp.concatenate([batch["embeds"].astype(dt), x], axis=1)
    B, L, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
        if cfg.mrope_sections:
            positions = jnp.repeat(positions[..., None], 3, axis=-1)
    return x, positions


def forward(params, batch, cfg: ModelConfig, last_only: bool = False):
    """Full-sequence forward (training / prefill).  Returns logits [B,L,V]
    (or [B,1,V] when ``last_only`` — the prefill path only needs the last
    position's logits; slicing BEFORE the unembedding matmul avoids a
    [B,L,V] materialization)."""
    x, positions = _embed_inputs(params, batch, cfg)

    def cycle_fn(x, cparams):
        if cfg.seq_shard:
            # Megatron-style sequence sharding: the scan-saved residual is
            # [B, L/model, D] per chip (16x smaller carry footprint); GSPMD
            # re-gathers L at attention entry and reduce-scatters after
            x = constrain(x, "dp", "model", None)
        else:
            x = constrain(x, "dp", None, None)   # anchor batch sharding
        for j, mt in enumerate(cfg.mixer_pattern):
            x = _apply_layer(cparams[f"layer{j}"], mt, x, positions, cfg)
        return x, None

    body = cycle_fn
    if cfg.remat == "full":
        body = jax.checkpoint(cycle_fn, prevent_cse=False)
    n_cycles = cfg.n_layers // cfg.cycle_len()
    if n_cycles:
        x, _ = jax.lax.scan(body, x, params["cycles"])
    for i, lp in enumerate(params["tail"]):
        x = _apply_layer(lp, cfg.mixer_pattern[i], x, positions, cfg)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bld,dv->blv", x, head.astype(x.dtype))
    return constrain(logits, "dp", None, "model")


def sharded_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Cross-entropy that keeps the vocab axis sharded.

    ``take_along_axis`` over a tensor-parallel vocab dim forces GSPMD to
    all-gather the full [B,L,V] logits (hundreds of GB at 1M tokens).  The
    one-hot contraction + logsumexp form reduces over the sharded axis
    instead: each shard contributes partial sums and only [B,L]-sized
    all-reduces cross chips."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.squeeze(m, -1) + jnp.log(
        jnp.sum(jnp.exp(logits - m), axis=-1))
    onehot = jax.nn.one_hot(targets, v, dtype=logits.dtype)
    tgt_logit = jnp.sum(logits * onehot, axis=-1)
    return lse - tgt_logit


def lm_loss(params, batch, cfg: ModelConfig):
    """Next-token cross-entropy on token positions (frontend prefix and the
    final position excluded)."""
    logits = forward(params, batch, cfg)
    n_prefix = 0 if batch.get("embeds") is None else batch["embeds"].shape[1]
    logits_tok = logits[:, n_prefix:-1, :]
    targets = batch["tokens"][:, 1:]
    return sharded_xent(logits_tok, targets).mean()


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = _dtype(cfg)
    n_cycles, n_tail = divmod(cfg.n_layers, cfg.cycle_len())

    def one_cycle(_):
        return {f"layer{j}": mix.MIXERS[mt][3](cfg, batch, max_len, dt)
                for j, mt in enumerate(cfg.mixer_pattern)}

    cache = {}
    if n_cycles:
        cache["cycles"] = jax.vmap(one_cycle)(jnp.arange(n_cycles))
    cache["tail"] = [mix.MIXERS[cfg.mixer_pattern[i]][3](cfg, batch, max_len, dt)
                     for i in range(n_tail)]
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One serving step: ``tokens`` [B, 1] new token ids, ``pos`` scalar
    (number of tokens already in the cache).  Returns (logits [B, V], cache).
    """
    dt = _dtype(cfg)
    x = params["embed"].astype(dt)[tokens]          # [B, 1, D]

    def cycle_fn(x, scanned):
        cparams, ccache = scanned
        new_cache = {}
        for j, mt in enumerate(cfg.mixer_pattern):
            lp = cparams[f"layer{j}"]
            decode_fn = mix.MIXERS[mt][2]
            h = rms_norm(x, lp["mixer_norm"], cfg.norm_eps)
            y, new_cache[f"layer{j}"] = decode_fn(
                lp["mixer"], h, ccache[f"layer{j}"], pos, cfg)
            x = x + y
            if cfg.ffn != "none":
                h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
                x = x + ffn_lib.apply_ffn(lp["ffn"], h, cfg)
        return x, new_cache

    new_cache = {"tail": []}
    if "cycles" in cache:
        x, new_cycles = jax.lax.scan(cycle_fn, x,
                                     (params["cycles"], cache["cycles"]))
        new_cache["cycles"] = new_cycles
    for i, lp in enumerate(params["tail"]):
        mt = cfg.mixer_pattern[i]
        h = rms_norm(x, lp["mixer_norm"], cfg.norm_eps)
        y, nc = mix.MIXERS[mt][2](lp["mixer"], h, cache["tail"][i], pos, cfg)
        new_cache["tail"].append(nc)
        x = x + y
        if cfg.ffn != "none":
            h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
            x = x + ffn_lib.apply_ffn(lp["ffn"], h, cfg)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bld,dv->blv", x, head.astype(x.dtype))
    logits = constrain(logits, "dp", None, "model")
    return logits[:, 0, :cfg.vocab], new_cache
