"""Feed-forward layers: dense SwiGLU/GeGLU and Mixture-of-Experts.

The MoE dispatch is where the paper's technique is a first-class feature in
the LM stack (DESIGN.md §4): top-k routing makes the token→expert activation
matrix block-sparse (density = top_k / n_experts ≈ 3.8% for DeepSeek-V2).
The dispatch is implemented as gather → grouped-GEMM → weighted scatter, the
TPU-native analogue of the SpDMM scatter-gather (Alg. 2): the Pairing Unit is
the capacity-indexed gather, the Update/Reduce are the per-expert matmul and
the weighted segment sum.  ``core.perfmodel.TPUV5E`` decides (statically,
since top-k is known) that the sparse path wins whenever
``top_k/n_experts < break-even`` — recorded per-config by ``moe_dispatch_report``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import glorot, swiglu, geglu


# ------------------------------------------------------------------ dense
def init_dense_ffn(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"w_gate": glorot(ks[0], (D, F)),
            "w_up": glorot(ks[1], (D, F)),
            "w_down": glorot(ks[2], (F, D))}


def dense_ffn(p, x, cfg: ModelConfig):
    fn = geglu if cfg.ffn == "geglu" else swiglu
    return fn(x, p["w_gate"], p["w_up"], p["w_down"])


# ------------------------------------------------------------------ MoE
def init_moe_ffn(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": glorot(ks[0], (D, E)),
        "w_gate": glorot(ks[1], (E, D, F)),
        "w_up": glorot(ks[2], (E, D, F)),
        "w_down": glorot(ks[3], (E, F, D)),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": glorot(ks2[0], (D, Fs)),
                       "w_up": glorot(ks2[1], (D, Fs)),
                       "w_down": glorot(ks2[2], (Fs, D))}
    return p


def moe_ffn(p, x, cfg: ModelConfig):
    """Token-choice top-k MoE with capacity-bounded gather/scatter dispatch.

    x: [B, L, D].  Experts axis is EP-sharded (see distributed/sharding.py);
    under pjit the gather/scatter lower to all-to-all style collectives.
    """
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * L
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                  # [T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # capacity-bounded slots per expert
    cap = max(1, int(T * K * cfg.capacity_factor / E))
    flat_e = top_e.reshape(-1)                              # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # [T*K, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot          # 1-based slot
    slot = jnp.max(pos_in_e, axis=-1) - 1                   # [T*K]
    keep = slot < cap                                       # overflow dropped
    dest = jnp.where(keep, flat_e * cap + slot, E * cap)    # OOB sentinel

    # scatter token ids into [E*cap] slot table (sentinel row dropped)
    token_id = jnp.repeat(jnp.arange(T), K)
    slot_token = jnp.zeros((E * cap + 1,), jnp.int32).at[dest].set(
        token_id + 1)                                       # 0 = empty
    slot_token = slot_token[:-1].reshape(E, cap)
    occupied = slot_token > 0
    gathered = jnp.where(occupied[..., None],
                         xf[jnp.maximum(slot_token - 1, 0)], 0.0)  # [E,cap,D]
    if cfg.moe_dispatch_shard:
        from repro.distributed.sharding import constrain
        gathered = constrain(gathered, "model", "dp", None)  # EP x token-slot

    # grouped GEMM over experts (EP-sharded einsum)
    g = jnp.einsum("ecd,edf->ecf", gathered, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", gathered, p["w_up"].astype(x.dtype))
    y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                     p["w_down"].astype(x.dtype))            # [E,cap,D]

    # weighted scatter back (Reduce step of Alg. 2)
    flat_w = top_p.reshape(-1).astype(x.dtype)              # [T*K]
    slot_w = jnp.zeros((E * cap + 1,), x.dtype).at[dest].set(
        jnp.where(keep, flat_w, 0.0))
    slot_w = slot_w[:-1].reshape(E, cap)
    contrib = y_e * slot_w[..., None]
    seg = jnp.maximum(slot_token - 1, 0).reshape(-1)
    out = jax.ops.segment_sum(
        jnp.where(occupied[..., None], contrib, 0.0).reshape(E * cap, D),
        seg, num_segments=T)

    if cfg.n_shared_experts:
        out = out + swiglu(xf, p["shared"]["w_gate"], p["shared"]["w_up"],
                           p["shared"]["w_down"])
    return out.reshape(B, L, D)


def moe_dispatch_report(cfg: ModelConfig, tokens: int) -> dict:
    """Static analyzer decision for the MoE dispatch (paper integration):
    density of the token→expert activation matrix and the chosen primitive
    under the TPU hardware model."""
    from repro.core.perfmodel import TPUV5E, TaskShape, t_dense, t_spdmm
    density = cfg.top_k / cfg.n_experts
    task = TaskShape(m=tokens, n=cfg.n_experts * cfg.moe_d_ff,
                     d=cfg.d_model, alpha_x=density, alpha_y=1.0)
    td, ts = t_dense(task, TPUV5E), t_spdmm(task, TPUV5E)
    return {"density": density, "t_dense": td, "t_sparse": ts,
            "primitive": "SpDMM(grouped-GEMM dispatch)" if ts < td else "GEMM"}


def init_ffn(key, cfg: ModelConfig):
    if cfg.ffn == "moe":
        return init_moe_ffn(key, cfg)
    if cfg.ffn == "none":
        return {}
    return init_dense_ffn(key, cfg)


def apply_ffn(p, x, cfg: ModelConfig):
    if cfg.ffn == "moe":
        return moe_ffn(p, x, cfg)
    if cfg.ffn == "none":
        return jnp.zeros_like(x)
    return dense_ffn(p, x, cfg)
