"""GNN model zoo of the paper: GCN, GraphSAGE(mean), GIN, SGC.

Every model is expressed against an abstract matmul ``mm(x, y, name)`` so the
same definition runs (a) through the DynasparseEngine (paper's accelerator),
(b) as a pure-jnp reference for tests.  2-layer configurations per §IV-B:
hidden 16 for CO/CI/PU, 128 for FL/NE/RE.

Kernel ordering follows Dynasparse: aggregation ``Â·X`` and transformation
``X·W`` are separate kernels; for GCN/SGC/SAGE we use the FLOPs-optimal
association (transform-first when in_dim > out_dim) — GIN's ``(1+ε)h + Â·h``
pins aggregation to the raw features, which is why GIN keeps a higher
aggregation cost (visible in Table VI).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core import shard_exec as _shard_exec
from repro.core import sparsity
from repro.core.engine import DynasparseEngine, EngineReport
from repro.core.primitives import SparseCOO
from repro.kernels import ops

MM = Callable[..., jax.Array]   # mm(x, y, name=...) -> z

MODELS = ("GCN", "GraphSAGE", "GIN", "SGC")


def _glorot(rng: np.random.Generator, m: int, n: int) -> jnp.ndarray:
    s = np.sqrt(2.0 / (m + n))
    return jnp.asarray(rng.normal(0, s, size=(m, n)).astype(np.float32))


def init_params(model: str, in_dim: int, hidden: int, out_dim: int,
                seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    if model == "GCN":
        return {"W1": _glorot(rng, in_dim, hidden),
                "W2": _glorot(rng, hidden, out_dim)}
    if model == "GraphSAGE":
        return {"Ws1": _glorot(rng, in_dim, hidden),
                "Wn1": _glorot(rng, in_dim, hidden),
                "Ws2": _glorot(rng, hidden, out_dim),
                "Wn2": _glorot(rng, hidden, out_dim)}
    if model == "GIN":
        return {"M1a": _glorot(rng, in_dim, hidden),
                "M1b": _glorot(rng, hidden, hidden),
                "M2a": _glorot(rng, hidden, hidden),
                "M2b": _glorot(rng, hidden, out_dim)}
    if model == "SGC":
        return {"W1": _glorot(rng, in_dim, hidden),
                "W2": _glorot(rng, hidden, out_dim)}
    raise ValueError(model)


def _transform_then_aggregate(mm: MM, adj, h, w, tag: str):
    """Â·(h·W) vs (Â·h)·W by FLOPs; both orders routed through ``mm``."""
    in_dim, out_dim = w.shape
    if in_dim >= out_dim:
        z = mm(h, w, name=f"{tag}-update")
        return mm(adj, z, name=f"{tag}-agg")
    z = mm(adj, h, name=f"{tag}-agg")
    return mm(z, w, name=f"{tag}-update")


def gcn_apply(mm: MM, adj, h, p) -> jax.Array:
    z = jax.nn.relu(_transform_then_aggregate(mm, adj, h, p["W1"], "l1"))
    return _transform_then_aggregate(mm, adj, z, p["W2"], "l2")


def sage_apply(mm: MM, adj, h, p) -> jax.Array:
    z_self = mm(h, p["Ws1"], name="l1-self")
    z_neigh = _transform_then_aggregate(mm, adj, h, p["Wn1"], "l1")
    z = jax.nn.relu(z_self + z_neigh)
    z2 = mm(z, p["Ws2"], name="l2-self") + _transform_then_aggregate(
        mm, adj, z, p["Wn2"], "l2")
    return z2


def gin_apply(mm: MM, adj, h, p, eps: float = 0.0) -> jax.Array:
    # aggregation is pinned to raw features: (1+ε)h + Â·h
    def dense(x):
        return jnp.asarray(x.todense()) if isinstance(x, SparseCOO) else x

    a1 = mm(adj, h, name="l1-agg")
    z = (1.0 + eps) * dense(h) + a1
    z = jax.nn.relu(mm(z, p["M1a"], name="l1-mlp1"))
    z = jax.nn.relu(mm(z, p["M1b"], name="l1-mlp2"))
    a2 = mm(adj, z, name="l2-agg")
    z = (1.0 + eps) * z + a2
    z = jax.nn.relu(mm(z, p["M2a"], name="l2-mlp1"))
    return mm(z, p["M2b"], name="l2-mlp2")


def sgc_apply(mm: MM, adj, h, p) -> jax.Array:
    # SGC: Â^2 · X · W1 · W2, no nonlinearity — optimal order transforms first
    z = mm(h, p["W1"], name="update1")
    z = mm(z, p["W2"], name="update2")
    z = mm(adj, z, name="agg1")
    return mm(adj, z, name="agg2")


APPLY = {"GCN": gcn_apply, "GraphSAGE": sage_apply, "GIN": gin_apply,
         "SGC": sgc_apply}


# ---------------------------------------------------------------- runners
def engine_mm(engine: DynasparseEngine) -> MM:
    def mm(x, y, name="kernel"):
        z, _ = engine.matmul(x, y, name=name)
        return z
    return mm


def reference_mm(x, y, name="kernel"):
    if isinstance(x, SparseCOO):
        x = jnp.asarray(x.todense())
    if isinstance(y, SparseCOO):
        y = jnp.asarray(y.todense())
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


@dataclasses.dataclass
class CompiledModel:
    """A whole model's kernel sequence fused into ONE jitted program.

    The GraphAGILE property at model scope: after one eager warmup pass has
    planned/packed/lowered every kernel, a steady-state micro-batch is a
    single compiled call — no Python per-kernel dispatch, no descriptor
    work, no per-kernel launches from the host's point of view.

    ``report`` is the warmup pass's :class:`EngineReport`; the schedule
    reports are plan-time simulations, so every later call on the same
    geometry would reproduce them verbatim — :meth:`fresh_report` hands the
    serving layer an identical (shallow) copy per batch.  Each call also
    credits ``plan_hits`` for its sparse kernels on ``stats``: a compiled
    call IS the reuse of those cached plans, and the hit-rate signal should
    keep reflecting that amortization.
    """
    model: str
    run: Callable                 # jitted replay: run(payload, h)
                                  #   -> (logits, activation diags)
    payload: list                 # per-kernel descriptor/pool pytrees
    report: EngineReport          # warmup report template (plan simulations)
    input_sketch: np.ndarray      # col-density sketch of the warmup features
    sketch_tile: int
    n_kernels: int
    n_sparse: int
    n_act: int = 0                # kernels on the capacity block-skip route
    stats: object | None = None   # CacheStats receiving call accounting
    faults: object | None = None  # FaultInjector probed at "compiled"
    calls: int = 0
    traces: int = 0               # distinct input signatures (jit retraces)
    # per-activation-kernel telemetry of the LAST call: stored/capacity/
    # logical block counts + overflow flag (device scalars; see
    # repro.core.dispatch.apply_activation_dispatch)
    last_activation: list = dataclasses.field(default_factory=list)
    _seen: set = dataclasses.field(default_factory=set)

    def drifted(self, h, threshold: float, *, max_rows: int = 256,
                eps: float = 0.0) -> bool:
        """Has the input's column density drifted past ``threshold`` from
        the features this program was compiled against?  The compiled path
        cannot sketch intermediate activations (they only exist inside the
        jitted program), so the input sketch is the invalidation signal —
        on drift the caller re-runs the eager path, whose per-kernel
        sketches replan stale assignments, and recompiles."""
        sk = sparsity.sketch_col_density(jnp.asarray(h), self.sketch_tile,
                                         max_rows=max_rows, eps=eps)
        return sparsity.density_drift(sk, self.input_sketch) > threshold

    def fresh_report(self) -> EngineReport:
        return EngineReport(kernels=list(self.report.kernels),
                            meta=list(self.report.meta))

    def __call__(self, h) -> jax.Array:
        # the whole-model compiled-execute site: a fault here exercises the
        # serving layer's compiled -> eager degradation ladder (the probe
        # runs BEFORE any stats are credited, so a failed call never skews
        # the steady-state hit accounting)
        if self.faults is not None:
            self.faults.probe("compiled", detail=self.model)
        h = jnp.asarray(h)
        sig = (tuple(h.shape), str(h.dtype))
        new = sig not in self._seen
        self._seen.add(sig)
        self.calls += 1
        self.traces += int(new)
        if self.stats is not None:
            if new:
                self.stats.trace_builds += 1
            else:
                self.stats.trace_cache_hits += 1
            self.stats.plan_hits += self.n_sparse
            # a compiled call equally replays the cached ActivationDispatch
            # descriptors of its block-skip kernels — credit act_hits so the
            # steady-state hit rate reflects that reuse (the builds happened
            # at warmup; without this the counter read "2 builds, 0 hits"
            # forever while every batch reused them)
            self.stats.act_hits += self.n_act
        logits, self.last_activation = self.run(self.payload, h)
        return logits


def compile_model(model: str, engine: DynasparseEngine, adj, h, params,
                  *, transport=None, activation_skip: bool = True,
                  activation_slack: float = 1.5,
                  activation_per_stripe: bool = True):
    """Fuse all layer kernels of (model, graph, feature shape) into a single
    jitted program; returns ``(warmup logits, CompiledModel | None)``.

    The warmup is ONE ordinary eager pass through ``engine.matmul`` — it
    plans, packs and lowers every adjacency kernel into the plan cache (all
    amortized state a later eager call would also use), while this function
    records each kernel's :class:`~repro.core.dispatch.CompiledDispatch`.
    The replay then re-traces the model with every adjacency kernel inlined
    as its compiled-dispatch body, the whole sequence under ONE ``jax.jit``.

    Activation-side (dense X) kernels choose their route per layer from the
    recorded warmup pass: when the warmup plan's Analyzer routed tasks to
    the sparse engine, the kernel is inlined as the capacity-padded
    block-skip route (:class:`~repro.core.dispatch.ActivationDispatch` —
    zero blocks of the intermediate features are skipped with FIXED shapes,
    budgeted at ``activation_slack`` headroom over the warmup's stored
    blocks — per stripe when ``activation_per_stripe`` (default), so skewed
    activations don't pad every stripe to the densest one's need; a batch
    that overflows the budget falls back to a dense GEMM
    inside the same program, never a retrace).  When the Analyzer sent
    everything to the dense engine — dense activations win — the kernel
    stays one dense Pallas GEMM.  ``activation_skip=False`` forces the
    dense-GEMM route for every activation kernel (PR-4 behaviour).

    ``None`` (second element) when any adjacency kernel has no compiled
    dispatch — non-literal/non-batched engines, canvas-misaligned geometry
    — in which case the caller keeps the eager path.

    ``transport`` optionally wraps the abstract ``mm`` with a representation
    transform (the serving layer's column-stack/row-unstack transport) and
    must be trace-pure.
    """
    transport = transport if transport is not None else (lambda mm: mm)
    h = jnp.asarray(h)
    # ("sparse", geom) | ("shard", (geom, band_rows, halo)) | ("act", geom)
    # | ("gemm", None) per kernel
    records: list[tuple[str, object]] = []
    payload: list = []
    compilable = [True]
    n0 = len(engine.report.kernels)

    def recording(x, y, name="kernel"):
        z, _ = engine.matmul(x, y, name=name)
        if isinstance(x, SparseCOO):
            if engine.mesh is not None:
                spair = engine.sharded_operands(engine.last_plan, x)
                if spair is None:
                    compilable[0] = False
                    records.append(("gemm", None))
                    payload.append(None)
                else:
                    sd, xd = spair
                    records.append(("shard",
                                    (sd.geom, sd.band_rows, sd.halo)))
                    payload.append({"arrays": dict(sd.arrays), "xd": xd})
                return z
            pair = engine.compiled_operands(engine.last_plan, x)
            if pair is None:
                compilable[0] = False
                records.append(("gemm", None))
                payload.append(None)
            else:
                d, xd = pair
                records.append(("sparse", d.geom))
                payload.append({"arrays": dict(d.arrays), "xd": xd})
        else:
            ad = (engine.activation_dispatch_for(
                      engine.last_plan, x, slack=activation_slack,
                      per_stripe=activation_per_stripe)
                  if activation_skip else None)
            if ad is None:
                records.append(("gemm", None))
                payload.append(None)
            else:
                records.append(("act", ad.geom))
                payload.append({"arrays": dict(ad.arrays)})
        return z

    logits = APPLY[model](transport(recording), adj, h, params)
    if not compilable[0]:
        return logits, None

    interpret = (ops.default_interpret() if engine.interpret is None
                 else engine.interpret)

    def replay(payload_, hh):
        ctr = itertools.count()
        act_diags = []

        def mm(x, y, name="kernel"):
            i = next(ctr)
            kind, geom = records[i]
            if kind == "gemm":
                return ops.gemm(jnp.asarray(x), jnp.asarray(y),
                                interpret=interpret, out_dtype=jnp.float32)
            p = payload_[i]
            if kind == "act":
                z, diag = _dispatch.apply_activation_dispatch(
                    geom, p["arrays"], x, y, interpret=interpret)
                act_diags.append(diag)
                return z
            if kind == "shard":
                sgeom, band_rows, halo = geom
                return _shard_exec.apply_sharded(
                    sgeom, band_rows, p["arrays"], p["xd"], y,
                    mesh=engine.mesh, interpret=interpret, halo=halo)
            return _dispatch.apply_dispatch(geom, p["arrays"], p["xd"], y,
                                            interpret=interpret)

        out = APPLY[model](transport(mm), adj, hh, params)
        return out, act_diags

    tn = engine.tile_n or min(128, int(h.shape[1]))
    sketch = sparsity.sketch_col_density(h, tn, max_rows=engine.sketch_rows,
                                         eps=engine.eps)
    report = EngineReport(kernels=list(engine.report.kernels[n0:]),
                          meta=list(engine.report.meta[n0:]))
    return logits, CompiledModel(
        model=model, run=jax.jit(replay), payload=payload, report=report,
        input_sketch=np.asarray(sketch), sketch_tile=tn,
        n_kernels=len(records),
        n_sparse=sum(1 for k, _ in records if k in ("sparse", "shard")),
        n_act=sum(1 for k, _ in records if k == "act"),
        stats=engine.cache.stats, faults=engine.faults)


def run_inference(model: str, engine: DynasparseEngine, adj, h, params):
    """Full-graph inference through the accelerator; returns logits and the
    engine report accumulated across all kernels.

    ``engine.reset()`` clears only the report — the engine's plan cache
    survives, so the adjacency's stripe densities, task assignment and packed
    BlockCSR stripes are computed on the first call and reused by every layer
    and every subsequent call on the same graph."""
    engine.reset()
    logits = APPLY[model](engine_mm(engine), adj, h, params)
    return logits, engine.report


def run_serving(model: str, engine: DynasparseEngine, adj, feature_batches,
                params, *, max_batch: int = 1):
    """Serving path: repeated inference over a stream of feature matrices on
    a FIXED graph — a thin wrapper over :mod:`repro.serving`.

    Request 1 populates the engine's plan cache; every later request hits it
    (no density re-measurement, no re-analysis, no re-packing), and the
    density sketch revalidates each hit against the live feature batch.
    ``max_batch > 1`` additionally coalesces the stream into micro-batches
    served with one plan/execute pass each.  Returns (list of logits, list
    of per-request engine reports — each the request's 1/k share of its
    micro-batch report; the raw batch reports live on the serving engine's
    ``stats.batch_reports``)."""
    from repro.serving import ServingConfig, ServingEngine

    with ServingEngine(model, params, engine=engine,
                       config=ServingConfig(max_batch=max_batch)) as srv:
        srv.register_graph("default", adj)
        outs = srv.serve(("default", jnp.asarray(h)) for h in feature_batches)
        by_id = sorted(srv.stats.requests, key=lambda r: r.request_id)
        return outs, [r.report for r in by_id]


def run_reference(model: str, adj, h, params):
    return APPLY[model](reference_mm, adj, h, params)
