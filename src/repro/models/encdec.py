"""Encoder-decoder backbone (Seamless-M4T medium assignment).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings [B, L_src, D] from ``input_specs``.  Encoder =
bidirectional self-attention stack; decoder = causal self-attention +
cross-attention stack.  Decode caches both the self-attn KV and the
projected encoder KV (computed once at prefill).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ffn as ffn_lib
from repro.models.layers import (decode_attention, flash_attention, glorot,
                                 rms_norm)
from repro.models.mixers import attn_cache, attn_decode, attn_train, init_attn


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _init_cross(key, cfg: ModelConfig):
    D = cfg.d_model
    Dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": glorot(ks[0], (D, cfg.n_heads * Dh)),
        "wk": glorot(ks[1], (D, cfg.n_kv_heads * Dh)),
        "wv": glorot(ks[2], (D, cfg.n_kv_heads * Dh)),
        "wo": glorot(ks[3], (cfg.n_heads * Dh, D)),
    }


def _cross_kv(p, enc_out, cfg):
    B, Ls, _ = enc_out.shape
    Dh = cfg.resolved_head_dim
    k = jnp.einsum("bld,dh->blh", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bld,dh->blh", enc_out, p["wv"].astype(enc_out.dtype))
    return (k.reshape(B, Ls, cfg.n_kv_heads, Dh),
            v.reshape(B, Ls, cfg.n_kv_heads, Dh))


def _cross_attend(p, x, k, v, cfg):
    B, Lt, _ = x.shape
    Dh = cfg.resolved_head_dim
    q = jnp.einsum("bld,dh->blh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, Lt, cfg.n_heads, Dh)
    out = flash_attention(q, k, v, causal=False)
    out = out.reshape(B, Lt, -1)
    return jnp.einsum("blh,hd->bld", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------- init
def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    D = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"attn": init_attn(k1, cfg), "attn_norm": jnp.ones((D,)),
                "ffn": ffn_lib.init_dense_ffn(k2, cfg),
                "ffn_norm": jnp.ones((D,))}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"attn": init_attn(k1, cfg), "attn_norm": jnp.ones((D,)),
                "cross": _init_cross(k2, cfg), "cross_norm": jnp.ones((D,)),
                "ffn": ffn_lib.init_dense_ffn(k3, cfg),
                "ffn_norm": jnp.ones((D,))}

    return {
        "embed": jax.random.normal(ks[0], (cfg.padded_vocab, D)) * 0.02,
        "enc_layers": jax.vmap(enc_layer)(
            jax.random.split(ks[1], cfg.n_enc_layers)),
        "dec_layers": jax.vmap(dec_layer)(
            jax.random.split(ks[2], cfg.n_layers)),
        "enc_norm": jnp.ones((D,)),
        "final_norm": jnp.ones((D,)),
        "lm_head": glorot(ks[3], (D, cfg.padded_vocab)),
    }


def abstract_params(cfg: ModelConfig, seed: int = 0):
    return jax.eval_shape(lambda: init_encdec(jax.random.PRNGKey(seed), cfg))


# ---------------------------------------------------------------- forward
def encode(params, frames, cfg: ModelConfig):
    """frames: [B, L_src, D] frontend-stub embeddings."""
    x = frames.astype(_dtype(cfg))
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    enc_cfg = dataclasses.replace(cfg, causal=False)  # bidirectional

    def layer(x, lp):
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        x = x + attn_train(lp["attn"], h, positions, enc_cfg)
        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + ffn_lib.dense_ffn(lp["ffn"], h, cfg)
        return x, None

    body = jax.checkpoint(layer, prevent_cse=False) if cfg.remat == "full" else layer
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig):
    """batch: {"frames": [B, Ls, D], "tokens": [B, Lt]} → logits [B, Lt, V]."""
    enc_out = encode(params, batch["frames"], cfg)
    x = params["embed"].astype(_dtype(cfg))[batch["tokens"]]
    B, Lt, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Lt, dtype=jnp.int32), (B, Lt))

    def layer(x, lp):
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        x = x + attn_train(lp["attn"], h, positions, cfg)
        h = rms_norm(x, lp["cross_norm"], cfg.norm_eps)
        k, v = _cross_kv(lp["cross"], enc_out, cfg)
        x = x + _cross_attend(lp["cross"], h, k, v, cfg)
        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + ffn_lib.dense_ffn(lp["ffn"], h, cfg)
        return x, None

    body = jax.checkpoint(layer, prevent_cse=False) if cfg.remat == "full" else layer
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bld,dv->blv", x, params["lm_head"].astype(x.dtype))


def lm_loss(params, batch, cfg: ModelConfig):
    from repro.models.lm import sharded_xent
    logits = forward(params, batch, cfg)
    targets = batch["tokens"][:, 1:]
    return sharded_xent(logits[:, :-1], targets).mean()


# ---------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_src: int, max_tgt: int):
    dt = _dtype(cfg)
    Dh = cfg.resolved_head_dim

    def one(_):
        return {
            "self": attn_cache(cfg, batch, max_tgt, dt),
            "cross_k": jnp.zeros((batch, max_src, cfg.n_kv_heads, Dh), dt),
            "cross_v": jnp.zeros((batch, max_src, cfg.n_kv_heads, Dh), dt),
        }

    return {"dec": jax.vmap(one)(jnp.arange(cfg.n_layers))}


def abstract_cache(cfg: ModelConfig, batch: int, max_src: int, max_tgt: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_src, max_tgt))


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One decoder step against a prefilled cross-attention cache."""
    dt = _dtype(cfg)
    x = params["embed"].astype(dt)[tokens]

    def layer(x, scanned):
        lp, lc = scanned
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        y, new_self = attn_decode(lp["attn"], h, lc["self"], pos, cfg)
        x = x + y
        h = rms_norm(x, lp["cross_norm"], cfg.norm_eps)
        B = x.shape[0]
        Dh = cfg.resolved_head_dim
        q = jnp.einsum("bld,dh->blh", h, lp["cross"]["wq"].astype(x.dtype))
        q = q.reshape(B, 1, cfg.n_heads, Dh)
        out = decode_attention(q, lc["cross_k"], lc["cross_v"],
                               lc["cross_k"].shape[1])
        out = out.reshape(B, 1, -1)
        x = x + jnp.einsum("blh,hd->bld", out,
                           lp["cross"]["wo"].astype(x.dtype))
        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + ffn_lib.dense_ffn(lp["ffn"], h, cfg)
        new_cache = dict(lc)
        new_cache["self"] = new_self
        return x, new_cache

    x, new_dec = jax.lax.scan(layer, x, (params["dec_layers"], cache["dec"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bld,dv->blv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0, :cfg.vocab], {"dec": new_dec}
