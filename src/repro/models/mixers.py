"""Sequence mixers: GQA attention, MLA (DeepSeek-V2), RG-LRU (Griffin /
RecurrentGemma), SSD (Mamba-2).

Uniform interface per mixer ``m``:
    init_m(key, cfg)                      -> params
    m_train(params, x, positions, cfg)    -> y            (full sequence)
    m_decode(params, x, cache, pos, cfg)  -> (y, cache)   (one step)
    m_cache(cfg, batch, max_len, dtype)   -> cache pytree
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_rope, decode_attention,
                                 flash_attention, flash_attention_vjp,
                                 glorot, rms_norm)


# ===================================================================== GQA
def init_attn(key, cfg: ModelConfig):
    D = cfg.d_model
    Dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": glorot(ks[0], (D, cfg.n_heads * Dh)),
        "wk": glorot(ks[1], (D, cfg.n_kv_heads * Dh)),
        "wv": glorot(ks[2], (D, cfg.n_kv_heads * Dh)),
        "wo": glorot(ks[3], (cfg.n_heads * Dh, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * Dh,))
        p["bk"] = jnp.zeros((cfg.n_kv_heads * Dh,))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * Dh,))
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    B, L, D = x.shape
    Dh = cfg.resolved_head_dim
    q = jnp.einsum("bld,dh->blh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bld,dh->blh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bld,dh->blh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, L, cfg.n_heads, Dh)
    k = k.reshape(B, L, cfg.n_kv_heads, Dh)
    v = v.reshape(B, L, cfg.n_kv_heads, Dh)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attn_train(p, x, positions, cfg: ModelConfig):
    q, k, v = _qkv(p, x, cfg, positions)
    attn = flash_attention_vjp if cfg.flash_vjp else flash_attention
    out = attn(q, k, v, causal=cfg.causal, window=cfg.window,
               causal_skip=cfg.flash_causal_skip)
    B, L = x.shape[:2]
    out = out.reshape(B, L, -1)
    return jnp.einsum("blh,hd->bld", out, p["wo"].astype(x.dtype))


def attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    Dh = cfg.resolved_head_dim
    # local attention only ever reads the last `window` positions
    clen = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, clen, cfg.n_kv_heads, Dh), dtype),
        "v": jnp.zeros((batch, clen, cfg.n_kv_heads, Dh), dtype),
    }


def attn_decode(p, x, cache, pos, cfg: ModelConfig):
    """``x``: [B, 1, D]; ``pos``: scalar current position (tokens so far)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope_sections:
        positions = jnp.repeat(positions[..., None], 3, axis=-1)
    q, k, v = _qkv(p, x, cfg, positions)
    clen = cache["k"].shape[1]
    slot = pos % clen if cfg.window else pos   # ring buffer for local attn
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    if cfg.window:
        # ring buffer: every stored slot is within the window by construction
        valid = jnp.minimum(pos + 1, clen)
        out = decode_attention(q, k_cache, v_cache, valid)
    else:
        out = decode_attention(q, k_cache, v_cache, pos + 1)
    out = out.reshape(B, 1, -1)
    y = jnp.einsum("blh,hd->bld", out, p["wo"].astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache}


# ===================================================================== MLA
def init_mla(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": glorot(ks[0], (D, H * qd)),
        "w_dkv": glorot(ks[1], (D, cfg.kv_lora_rank)),
        "w_kpe": glorot(ks[2], (D, cfg.qk_rope_head_dim)),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,)),
        "w_uk": glorot(ks[3], (cfg.kv_lora_rank, H * cfg.qk_nope_head_dim)),
        "w_uv": glorot(ks[4], (cfg.kv_lora_rank, H * cfg.v_head_dim)),
        "wo": glorot(ks[5], (H * cfg.v_head_dim, D)),
    }


def _mla_qc(p, x, cfg: ModelConfig, positions):
    """Queries + compressed KV stream (the only thing MLA caches)."""
    B, L, _ = x.shape
    H = cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = jnp.einsum("bld,dh->blh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, L, H, nope + rope_d)
    qn, qr = q[..., :nope], q[..., nope:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    kv_c = jnp.einsum("bld,dr->blr", x, p["w_dkv"].astype(x.dtype))
    kv_c = rms_norm(kv_c, p["kv_norm"], cfg.norm_eps)
    kpe = jnp.einsum("bld,dr->blr", x, p["w_kpe"].astype(x.dtype))
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return qn, qr, kv_c, kpe


def mla_train(p, x, positions, cfg: ModelConfig):
    B, L, _ = x.shape
    H = cfg.n_heads
    qn, qr, kv_c, kpe = _mla_qc(p, x, cfg, positions)
    # decompress K/V (training path; decode uses the absorbed form)
    k_n = jnp.einsum("blr,rh->blh", kv_c, p["w_uk"].astype(x.dtype))
    k_n = k_n.reshape(B, L, H, cfg.qk_nope_head_dim)
    v = jnp.einsum("blr,rh->blh", kv_c, p["w_uv"].astype(x.dtype))
    v = v.reshape(B, L, H, cfg.v_head_dim)
    k = jnp.concatenate(
        [k_n, jnp.broadcast_to(kpe[:, :, None, :],
                               (B, L, H, cfg.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)
    # pad V head dim up to QK head dim for the shared flash kernel
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qd - cfg.v_head_dim)))
    attn = flash_attention_vjp if cfg.flash_vjp else flash_attention
    out = attn(q, k, v_p, causal=True,
               causal_skip=cfg.flash_causal_skip)[..., :cfg.v_head_dim]
    out = out.reshape(B, L, H * cfg.v_head_dim)
    return jnp.einsum("blh,hd->bld", out, p["wo"].astype(x.dtype))


def mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "kv_c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    """Absorbed-matmul MLA decode: attention runs in the rank-512 latent
    space; the cache is (kv_c, k_pe) — 576 floats/token vs H*(nope+rope+v)."""
    B = x.shape[0]
    H = cfg.n_heads
    positions = jnp.full((B, 1), pos, jnp.int32)
    qn, qr, kv_c, kpe = _mla_qc(p, x, cfg, positions)
    kv_cache = jax.lax.dynamic_update_slice(cache["kv_c"], kv_c, (0, pos, 0))
    pe_cache = jax.lax.dynamic_update_slice(cache["kpe"], kpe, (0, pos, 0))
    # absorb W_uk into the query:  q_lat [B,1,H,lora]
    w_uk = p["w_uk"].astype(x.dtype).reshape(
        cfg.kv_lora_rank, H, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", qn, w_uk)
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    s = (jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32),
                    kv_cache.astype(jnp.float32))
         + jnp.einsum("bqhr,bkr->bhqk", qr.astype(jnp.float32),
                      pe_cache.astype(jnp.float32))) * scale
    mask = jnp.arange(kv_cache.shape[1])[None] < pos + 1
    s = jnp.where(mask[None, None], s, -1e30)
    prob = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhqk,bkr->bqhr", prob,
                         kv_cache.astype(jnp.float32)).astype(x.dtype)
    w_uv = p["w_uv"].astype(x.dtype).reshape(
        cfg.kv_lora_rank, H, cfg.v_head_dim)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv)
    out = out.reshape(B, 1, H * cfg.v_head_dim)
    y = jnp.einsum("blh,hd->bld", out, p["wo"].astype(x.dtype))
    return y, {"kv_c": kv_cache, "kpe": pe_cache}


# ===================================================================== RG-LRU
_LRU_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    D = cfg.d_model
    dr = cfg.d_rnn or D
    ks = jax.random.split(key, 5)
    return {
        "w_in": glorot(ks[0], (D, dr)),       # recurrent branch
        "w_gate": glorot(ks[1], (D, dr)),     # GeLU gate branch
        "w_out": glorot(ks[2], (dr, D)),
        "conv_w": glorot(ks[3], (cfg.conv_width, dr)) * 0.5,
        "conv_b": jnp.zeros((dr,)),
        # diagonal RG-LRU gates (RecurrentGemma uses block-diagonal; diagonal
        # keeps the same recurrence structure at framework scale)
        "w_rgate": jnp.zeros((dr,)),
        "b_rgate": jnp.zeros((dr,)),
        "w_igate": jnp.zeros((dr,)),
        "b_igate": jnp.zeros((dr,)),
        # Λ init so a = σ(Λ)^c ∈ (0.9, 0.999)
        "lam": jnp.asarray(
            np.log(np.expm1(-np.log(np.linspace(0.9, 0.999, dr)) / _LRU_C)),
            jnp.float32),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv, width W (train path).  x: [B, L, C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    L = x.shape[1]
    for i in range(W):
        out = out + xp[:, i:i + L] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _lru_gates(p, u):
    """a_t (decay) and gated input for the linear recurrence."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_rgate"] + p["b_rgate"])
    i = jax.nn.sigmoid(uf * p["w_igate"] + p["b_igate"])
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, gated


def rglru_train(p, x, positions, cfg: ModelConfig):
    del positions
    u = jnp.einsum("bld,dr->blr", x, p["w_in"].astype(x.dtype))
    g = jnp.einsum("bld,dr->blr", x, p["w_gate"].astype(x.dtype))
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, gated = _lru_gates(p, u)
    # h_t = a_t h_{t-1} + gated_t  — parallel associative scan over time
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = h.astype(x.dtype) * jax.nn.gelu(g)
    return jnp.einsum("blr,rd->bld", y, p["w_out"].astype(x.dtype))


def rglru_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    dr = cfg.d_rnn or cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype),
    }


def rglru_decode(p, x, cache, pos, cfg: ModelConfig):
    del pos
    B = x.shape[0]
    u = jnp.einsum("bld,dr->blr", x, p["w_in"].astype(x.dtype))   # [B,1,dr]
    g = jnp.einsum("bld,dr->blr", x, p["w_gate"].astype(x.dtype))
    hist = jnp.concatenate([cache["conv"], u], axis=1)            # [B,W,dr]
    w = p["conv_w"].astype(x.dtype)
    u_c = jnp.einsum("bwr,wr->br", hist, w)[:, None] + p["conv_b"].astype(x.dtype)
    a, gated = _lru_gates(p, u_c)
    h = a[:, 0] * cache["h"] + gated[:, 0]
    y = h[:, None].astype(x.dtype) * jax.nn.gelu(g)
    out = jnp.einsum("blr,rd->bld", y, p["w_out"].astype(x.dtype))
    return out, {"h": h, "conv": hist[:, 1:]}


# ===================================================================== SSD
def init_ssd(key, cfg: ModelConfig):
    D = cfg.d_model
    di, n, H = cfg.d_inner, cfg.d_state, cfg.n_ssd_heads
    ks = jax.random.split(key, 4)
    return {
        "w_in": glorot(ks[0], (D, 2 * di + 2 * n + H)),  # z, x, B, C, dt
        "conv_w": glorot(ks[1], (cfg.conv_width, di + 2 * n)) * 0.5,
        "conv_b": jnp.zeros((di + 2 * n,)),
        "a_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, H)), jnp.float32),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 1e-1, H))), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_norm": jnp.ones((di,)),
        "w_out": glorot(ks[2], (di, D)),
    }


def _segsum(x):
    """x: [..., T] → lower-triangular pairwise sums Σ_{j<i..} (f32)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def _ssd_scan(x_dt, dA, Bm, Cm, chunk):
    """Chunked SSD (Mamba-2 Listing 1).  x_dt: [b,l,h,p] (pre-multiplied by
    dt), dA: [b,l,h], B,C: [b,l,n].  Returns y [b,l,h,p]."""
    b, l, h, p = x_dt.shape
    n = Bm.shape[-1]
    q = min(chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    x_dt = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
    Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
    Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    xc = x_dt.reshape(b, nc, q, h, p)
    Ac = dA.reshape(b, nc, q, h).transpose(0, 3, 1, 2)      # [b,h,c,q]
    Bc = Bm.reshape(b, nc, q, n)
    Cc = Cm.reshape(b, nc, q, n)

    A_cum = jnp.cumsum(Ac, axis=-1)                          # [b,h,c,q]
    Lmat = jnp.exp(_segsum(Ac))                              # [b,h,c,q,q]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cc, Bc, Lmat, xc)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)          # [b,h,c,q]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)
    chunk_decay = jnp.exp(A_cum[..., -1])                    # [b,h,c]

    def body(s, inp):
        st, dec = inp                    # st [b,h,p,n], dec [b,h]
        s_next = s * dec[..., None, None] + st
        return s_next, s                 # emit state BEFORE this chunk

    s0 = jnp.zeros((b, h, p, n), x_dt.dtype)
    _, prev_states = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [b,c,h,p,n]
    state_decay = jnp.exp(A_cum)                             # [b,h,c,q]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, nc * q, h, p)
    return y[:, :l]


def _ssd_proj(p, x, cfg: ModelConfig):
    di, n, H = cfg.d_inner, cfg.d_state, cfg.n_ssd_heads
    zxbcdt = jnp.einsum("bld,df->blf", x, p["w_in"].astype(x.dtype))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * n]
    dt_raw = zxbcdt[..., 2 * di + 2 * n:]
    return z, xbc, dt_raw


def _ssd_post(p, y, z, x_in, d_skip, cfg: ModelConfig):
    b, l = y.shape[:2]
    y = y + d_skip * x_in                    # D skip connection
    y = y.reshape(b, l, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return jnp.einsum("blf,fd->bld", y, p["w_out"].astype(y.dtype))


def ssd_train(p, x, positions, cfg: ModelConfig):
    del positions
    di, n, H = cfg.d_inner, cfg.d_state, cfg.n_ssd_heads
    P = cfg.ssd_head_dim
    z, xbc, dt_raw = _ssd_proj(p, x, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x_in = xbc[..., :di].reshape(*x.shape[:2], H, P)
    Bm = xbc[..., di:di + n]
    Cm = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [b,l,H]
    dA = -jnp.exp(p["a_log"]) * dt
    x_dt = x_in * dt[..., None].astype(x.dtype)
    y = _ssd_scan(x_dt.astype(jnp.float32), dA, Bm.astype(jnp.float32),
                  Cm.astype(jnp.float32), cfg.ssd_chunk).astype(x.dtype)
    return _ssd_post(p, y, z, x_in, p["d_skip"][:, None].astype(x.dtype), cfg)


def ssd_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "state": jnp.zeros((batch, cfg.n_ssd_heads, cfg.ssd_head_dim,
                            cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.d_state), dtype),
    }


def ssd_decode(p, x, cache, pos, cfg: ModelConfig):
    del pos
    di, n, H = cfg.d_inner, cfg.d_state, cfg.n_ssd_heads
    P = cfg.ssd_head_dim
    B = x.shape[0]
    z, xbc, dt_raw = _ssd_proj(p, x, cfg)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)
    w = p["conv_w"].astype(x.dtype)
    xbc_c = jnp.einsum("bwf,wf->bf", hist, w)[:, None] + p["conv_b"].astype(x.dtype)
    xbc_c = jax.nn.silu(xbc_c)
    x_in = xbc_c[..., :di].reshape(B, 1, H, P)
    Bm = xbc_c[..., di:di + n]                     # [B,1,n]
    Cm = xbc_c[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    dA = jnp.exp(-jnp.exp(p["a_log"]) * dt)        # [B,H]
    # S = dA·S + dt·x ⊗ B ;  y = C·S
    s = cache["state"] * dA[..., None, None]
    s = s + jnp.einsum("bhp,bn,bh->bhpn", x_in[:, 0].astype(jnp.float32),
                       Bm[:, 0].astype(jnp.float32), dt)
    y = jnp.einsum("bhpn,bn->bhp", s, Cm[:, 0].astype(jnp.float32))
    y = y[:, None].astype(x.dtype)                 # [B,1,H,P]
    out = _ssd_post(p, y, z, x_in, p["d_skip"][:, None].astype(x.dtype), cfg)
    return out, {"state": s, "conv": hist[:, 1:]}


# ===================================================================== registry
MIXERS = {
    "attn": (init_attn, attn_train, attn_decode, attn_cache),
    "mla": (init_mla, mla_train, mla_decode, mla_cache),
    "rglru": (init_rglru, rglru_train, rglru_decode, rglru_cache),
    "ssd": (init_ssd, ssd_train, ssd_decode, ssd_cache),
}
