"""Version shims over the jax API surface that moved between releases.

The pinned CI environment runs jax 0.4.37, where:

- ``jax.make_mesh`` exists but does not take ``axis_types`` (and
  ``jax.sharding.AxisType`` does not exist at all);
- ``jax.shard_map`` is still ``jax.experimental.shard_map.shard_map`` and
  spells its replication check ``check_rep`` instead of ``check_vma``.

Everything SPMD in this repo goes through these two wrappers so the same
code runs on 0.4.37 and on current jax without feature gates in the tests.
``backend_kind`` is the compat-visible device-kind probe the calibration
subsystem keys its measurements on.
"""
from __future__ import annotations

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def backend_kind() -> str:
    """The active jax backend kind ("cpu", "tpu", "gpu").

    The calibration cache key's device-kind component: measurements taken on
    one backend must never be replayed on another, and the fallback
    ``HardwareModel`` for an uncalibrated engine is chosen from this value
    (``repro.core.perfmodel.runtime_fallback``)."""
    return jax.default_backend()


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` / ``jax.experimental.shard_map.shard_map``.

    ``check`` maps to ``check_vma`` (new) / ``check_rep`` (old) — both
    toggle the same replication-mismatch validation.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
