"""Model / run configuration system.

``ModelConfig`` is a plain frozen dataclass covering every assigned
architecture family (dense / MoE / MLA / hybrid RG-LRU / SSD / enc-dec /
VLM).  ``ShapeConfig`` describes the four assigned input-shape cells.
Architectures register themselves in ``repro.configs`` (one module per arch).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

Family = Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    # mixer pattern: one entry per layer cycle, e.g. ("rglru","rglru","attn")
    # cycled over n_layers; default all-attention
    mixer_pattern: Sequence[str] = ("attn",)
    ffn: str = "swiglu"                # swiglu | geglu | moe | none
    # -- attention extras
    causal: bool = True                # False: bidirectional (encoder stacks)
    window: int | None = None          # local attention window (recurrentgemma)
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE
    # -- MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # -- MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # -- RG-LRU / hybrid
    d_rnn: int = 0                     # RG-LRU width (recurrentgemma: d_model)
    conv_width: int = 4
    # -- SSD (mamba2)
    d_state: int = 0
    expand: int = 2
    ssd_head_dim: int = 64
    ssd_chunk: int = 256
    # -- enc-dec
    n_enc_layers: int = 0              # 0 -> decoder-only
    # -- training
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"                # none | full (per-layer checkpoint)
    microbatches: int = 1              # grad-accumulation splits of the batch
    opt_dtype: str = "float32"         # Adam moment dtype (bf16 for 200B+)
    seq_shard: bool = False            # Megatron-style sequence-sharded
                                       # activations between layers (§Perf)
    flash_causal_skip: bool = False    # unrolled-q flash: skip fully-masked
                                       # KV blocks (halves causal FLOPs, §Perf)
    moe_dispatch_shard: bool = False   # shard [E, cap, D] dispatch over
                                       # (model=EP, dp=token-slots) (§Perf)
    flash_vjp: bool = False            # recompute-based flash backward:
                                       # no stacked f32 probability residuals
    # fraction of prefix positions that come from the modality frontend stub
    # (audio frames / vision patches); input_specs provides embeddings
    frontend_prefix: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding tables are padded to a multiple of 256 so the vocab axis
        shards over any mesh (tokens never index the pad; logits beyond
        ``vocab`` are sliced off at the serving boundary)."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:          # SSD inner width
        return self.expand * self.d_model

    @property
    def n_ssd_heads(self) -> int:
        return self.d_inner // self.ssd_head_dim

    def cycle_len(self) -> int:
        return len(self.mixer_pattern)

    # --- parameter count (for 6ND model-flops accounting) -----------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count (embedding included once)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        Dh = self.resolved_head_dim
        total = V * D * (1 if self.tie_embeddings else 2)
        per_cycle = 0
        for mixer in self.mixer_pattern:
            if mixer == "attn":
                if self.kv_lora_rank:
                    qd = self.qk_nope_head_dim + self.qk_rope_head_dim
                    per_cycle += D * self.n_heads * qd          # W_q
                    per_cycle += D * (self.kv_lora_rank + self.qk_rope_head_dim)
                    per_cycle += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_head_dim + self.v_head_dim)
                    per_cycle += self.n_heads * self.v_head_dim * D
                else:
                    per_cycle += D * self.n_heads * Dh
                    per_cycle += 2 * D * self.n_kv_heads * Dh
                    per_cycle += self.n_heads * Dh * D
            elif mixer == "rglru":
                dr = self.d_rnn or D
                per_cycle += 2 * D * dr + dr * D   # in/out projections (x2 gates)
                per_cycle += dr * self.conv_width + 3 * dr  # conv + lru gates
            elif mixer == "ssd":
                di, n = self.d_inner, self.d_state
                per_cycle += D * (2 * di + 2 * n + self.n_ssd_heads)
                per_cycle += di * D
            if self.ffn == "swiglu" or self.ffn == "geglu":
                per_cycle += 3 * D * F
            elif self.ffn == "moe":
                per_cycle += D * self.n_experts  # router
                e = self.n_experts + self.n_shared_experts
                per_cycle += 3 * D * self.moe_d_ff * (
                    (self.top_k + self.n_shared_experts) if active_only else e)
        n_cycles = L / len(self.mixer_pattern)
        total += int(per_cycle * n_cycles)
        if self.n_enc_layers:
            # encoder layers: self-attn + ffn; decoder adds cross-attn
            enc = self.n_enc_layers * (4 * D * self.n_heads * Dh + 3 * D * F)
            cross = L * (2 * D * self.n_kv_heads * Dh + 2 * D * self.n_heads * Dh)
            total += enc + cross
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# sub-quadratic archs that run long_500k (others skip-by-design)
SUBQUADRATIC = {"recurrentgemma-9b", "mamba2-780m"}
