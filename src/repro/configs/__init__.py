"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs import (deepseek_7b, deepseek_v2_236b, deepseek_v2_lite_16b,
                           mamba2_780m, mistral_large_123b, phi3_mini_3_8b,
                           qwen2_5_3b, qwen2_vl_72b, recurrentgemma_9b,
                           seamless_m4t_medium)
from repro.configs.base import SHAPES, SUBQUADRATIC, ModelConfig, ShapeConfig

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (phi3_mini_3_8b, mistral_large_123b, qwen2_5_3b, deepseek_7b,
              recurrentgemma_9b, deepseek_v2_236b, deepseek_v2_lite_16b,
              seamless_m4t_medium, mamba2_780m, qwen2_vl_72b)
}

__all__ = ["ARCHS", "SHAPES", "SUBQUADRATIC", "ModelConfig", "ShapeConfig"]
