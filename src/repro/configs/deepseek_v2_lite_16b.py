"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H vocab=102400, MLA
kv_lora=512, MoE 2 shared + 64 routed top-6 [arXiv:2405.04434; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    mixer_pattern=("mla",),
    kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
    v_head_dim=128,
    ffn="moe", n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    microbatches=4,
)
