"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].
Sub-quadratic: runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=0, vocab=50280,
    mixer_pattern=("ssd",), ffn="none",
    d_state=128, expand=2, ssd_head_dim=64, ssd_chunk=256, microbatches=4,
)
