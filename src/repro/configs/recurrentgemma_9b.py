"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1, MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention, pattern 2 recurrent : 1
attention [arXiv:2402.19427; unverified].  Sub-quadratic: runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    mixer_pattern=("rglru", "rglru", "attn"),
    ffn="geglu", window=2048, d_rnn=4096, microbatches=8,
)
