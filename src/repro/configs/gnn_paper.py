"""The paper's own workload: GNN models x datasets on the Dynasparse-style
heterogeneous engine (see repro.core / repro.models.gnn)."""
from repro.data.graphs import DATASETS
from repro.models.gnn import MODELS

GNN_MODELS = MODELS
GNN_DATASETS = tuple(DATASETS)
