"""Reduced-size configs of the same family for CPU smoke tests.

Every assigned architecture gets a structurally-identical miniature (same
mixer pattern, same FFN type, same MLA/MoE/SSD wiring — small widths, few
layers, tiny vocab).  Full configs are exercised only via the dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    cyc = cfg.cycle_len()
    n_layers = cyc * 2 + (1 if cfg.n_layers % cyc else 0)  # cycles + tail
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=0 if cfg.ffn == "none" else 128,
        vocab=256,
        head_dim=16,
        remat="none",
    )
    if cfg.kv_lora_rank:
        kw.update(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16)
    if cfg.ffn == "moe":
        kw.update(n_experts=8, n_shared_experts=cfg.n_shared_experts and 1,
                  top_k=2, moe_d_ff=32)
    if "rglru" in cfg.mixer_pattern:
        kw.update(d_rnn=64, window=16)
    if "ssd" in cfg.mixer_pattern:
        kw.update(d_state=16, ssd_head_dim=16, expand=2, ssd_chunk=8)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, n_layers=2)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(2, 3, 3))   # sums to head_dim/2 = 8
    return dataclasses.replace(cfg, **kw)
