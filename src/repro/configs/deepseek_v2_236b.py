"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536(moe)
vocab=102400, MLA kv_lora=512, MoE 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    mixer_pattern=("mla",),
    kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
    v_head_dim=128,
    ffn="moe", n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    microbatches=8, opt_dtype="bfloat16",
)
