"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].  The audio
frontend is a STUB: input_specs provides precomputed frame embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206,
    n_enc_layers=12, ffn="swiglu", microbatches=2,
)
