"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The VERY FIRST lines force 512 host placeholder devices — before any other
import, since jax locks the device count on first init.  Do NOT set this
globally; only the dry-run needs it.

Usage:
    python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--jobs-file results/dryrun]

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` with memory analysis,
cost analysis, per-collective bytes and the roofline terms. ``--all`` drives
one subprocess per cell (isolation: a pathological cell cannot kill the
sweep); completed cells are skipped, so the sweep is resumable.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import gc
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

DEFAULT_OUT = Path("results/dryrun")


def _bf16_params(params):
    """Serving-time weight dtype: bf16 copies of the f32 masters (§Perf
    'bf16_params' — halves per-step weight reads and drops the per-step
    f32→bf16 cast traffic)."""
    import jax
    import jax.numpy as jnp

    return jax.tree.map(
        lambda s: (jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                   if s.dtype == jnp.float32 else s), params)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             save_hlo: bool = False, opts: tuple[str, ...] = ()) -> dict:
    """``opts`` enables §Perf hillclimb variants (baseline = no opts):
    seq_shard, flash_skip, moe_shard, infer_tp (TP-only inference params),
    mb2 (double microbatches)."""
    import dataclasses

    import jax

    from repro.configs import ARCHS, SHAPES
    from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                            params_shardings)
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (abstract_state, make_prefill_step,
                                    make_serve_step, make_train_step)
    from repro.models.registry import build_model, cell_is_runnable, input_specs
    from repro.optim.adamw import AdamWConfig

    cfg = ARCHS[arch]
    if "seq_shard" in opts:
        cfg = dataclasses.replace(cfg, seq_shard=True)
    if "flash_skip" in opts:
        cfg = dataclasses.replace(cfg, flash_causal_skip=True)
    if "moe_shard" in opts:
        cfg = dataclasses.replace(cfg, moe_dispatch_shard=True)
    if "mb2" in opts:
        cfg = dataclasses.replace(cfg, microbatches=cfg.microbatches * 2)
    if "flash_vjp" in opts:
        cfg = dataclasses.replace(cfg, flash_vjp=True)
    shape = SHAPES[shape_name]
    runnable, reason = cell_is_runnable(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "opts": list(opts), "timestamp": time.time()}
    if not runnable:
        result.update(status="skipped-by-design", reason=reason)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    bundle = build_model(cfg)
    specs = input_specs(cfg, shape)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            state = abstract_state(bundle)
            state_sh = {"params": params_shardings(state["params"], mesh),
                        "opt": {
                            "mu": params_shardings(state["opt"]["mu"], mesh),
                            "nu": params_shardings(state["opt"]["nu"], mesh),
                            "step": jax.NamedSharding(
                                mesh, jax.sharding.PartitionSpec())}}
            batch_sh = batch_shardings(specs, mesh)
            step = make_train_step(bundle, AdamWConfig())
            fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=0)
            lowered = fn.lower(state, specs)
        elif shape.kind == "prefill":
            params = bundle.abstract_params()
            if "bf16_params" in opts:
                params = _bf16_params(params)
            p_sh = params_shardings(params, mesh,
                                    fsdp="infer_tp" not in opts)
            b_sh = batch_shardings(specs, mesh)
            fn = jax.jit(make_prefill_step(bundle),
                         in_shardings=(p_sh, b_sh))
            lowered = fn.lower(params, specs)
        else:  # decode
            params = bundle.abstract_params()
            if "bf16_params" in opts:
                params = _bf16_params(params)
            cache = bundle.abstract_cache(shape.global_batch, shape.seq_len)
            p_sh = params_shardings(params, mesh,
                                    fsdp="infer_tp" not in opts)
            c_sh = cache_shardings(cache, mesh)
            b_sh = batch_shardings(specs, mesh)
            fn = jax.jit(make_serve_step(bundle),
                         in_shardings=(p_sh, c_sh, b_sh),
                         donate_argnums=1)
            lowered = fn.lower(params, cache, specs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    # loop-aware per-chip costs (cost_analysis counts while bodies once —
    # scanned layers/microbatches would be undercounted ~1000x)
    lc = rl.hlo_cost(hlo)
    flops = lc["flops"]
    bytes_acc = lc["bytes"]
    coll = lc["collectives"]
    terms = rl.roofline_terms(flops, bytes_acc, sum(coll.values()), n_chips)
    mf = rl.model_flops(cfg, shape)                # whole-cluster useful flops

    result.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
                / 1e9, 3),
        },
        cost={"flops": flops, "bytes_accessed": bytes_acc,
              "xla_flops_once": float(cost.get("flops", 0.0)),
              "xla_bytes_once": float(cost.get("bytes accessed", 0.0))},
        collectives=coll,
        roofline=terms,
        model_flops=mf,
        useful_flops_ratio=(round(mf / (flops * n_chips), 4)
                            if flops else None),
        params_b=round(cfg.param_count() / 1e9, 3),
        params_active_b=round(cfg.param_count(active_only=True) / 1e9, 3),
    )
    if save_hlo:
        hlo_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.hlo"
        hlo_path.write_text(hlo)
        result["hlo_path"] = str(hlo_path)
    del compiled, lowered, fn
    gc.collect()
    return result


def all_cells():
    from repro.configs import ARCHS, SHAPES
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                yield arch, shape, mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list of §Perf variants: seq_shard,"
                         "flash_skip,moe_shard,infer_tp,mb2")
    ap.add_argument("--timeout", type=int, default=3000,
                    help="per-cell timeout (s) in --all mode")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = list(all_cells())
        done = failed = 0
        for arch, shape, mesh in cells:
            path = out_dir / f"{arch}__{shape}__{mesh}.json"
            if path.exists():
                done += 1
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", str(out_dir)]
            if args.save_hlo:
                cmd.append("--save-hlo")
            print(f"[dryrun] {arch} x {shape} x {mesh} ...", flush=True)
            try:
                rc = subprocess.run(cmd, timeout=args.timeout).returncode
            except subprocess.TimeoutExpired:
                rc = -9
            if rc != 0 and not path.exists():
                path.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "mesh": mesh,
                     "status": "failed", "returncode": rc}, indent=1))
                failed += 1
            else:
                done += 1
        print(f"[dryrun] complete: {done} ok/skipped, {failed} failed "
              f"of {len(cells)}")
        return

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    opts = tuple(o for o in args.opt.split(",") if o)
    try:
        result = run_cell(args.arch, args.shape, args.mesh, out_dir,
                          save_hlo=args.save_hlo, opts=opts)
    except Exception as e:  # recorded, not raised: the sweep must continue
        result = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "opts": list(opts),
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    suffix = ("__" + "_".join(opts)) if opts else ""
    path = out_dir / f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json"
    path.write_text(json.dumps(result, indent=1))
    status = result.get("status")
    print(f"[dryrun] {args.arch} x {args.shape} x {args.mesh}: {status}")
    if status == "ok":
        r = result["roofline"]
        print(f"  compile {result['compile_s']}s | peak/dev "
              f"{result['memory']['peak_per_device_gb']} GB | "
              f"compute {r['compute_s']:.3e}s memory {r['memory_s']:.3e}s "
              f"collective {r['collective_s']:.3e}s -> {r['dominant']}")
    elif status == "error":
        print(result["error"])
        sys.exit(1)


if __name__ == "__main__":
    main()
