"""Production mesh construction.

Single pod: 16 x 16 = 256 chips (v5e pod), axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis maps to the DCN/ICI-superpod boundary; batch and FSDP shard over
it, tensor-parallel stays within a pod.

Functions only — importing this module never touches jax device state.
Mesh construction goes through ``repro.compat.make_mesh`` so the same code
runs on jax 0.4.37 (no ``AxisType``) and on current jax.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int, *, model_parallel: int = 1,
                          pods: int = 1) -> jax.sharding.Mesh:
    """Elastic variant: largest (pod, data, model) mesh for a device count
    (used by distributed.elastic after failures)."""
    assert n_devices % (model_parallel * pods) == 0, (n_devices,
                                                      model_parallel, pods)
    data = n_devices // (model_parallel * pods)
    if pods > 1:
        return compat.make_mesh((pods, data, model_parallel),
                                ("pod", "data", "model"))
    return compat.make_mesh((data, model_parallel), ("data", "model"))
