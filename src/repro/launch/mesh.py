"""Production mesh construction.

Single pod: 16 x 16 = 256 chips (v5e pod), axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis maps to the DCN/ICI-superpod boundary; batch and FSDP shard over
it, tensor-parallel stays within a pod.

Functions only — importing this module never touches jax device state.
Mesh construction goes through ``repro.compat.make_mesh`` so the same code
runs on jax 0.4.37 (no ``AxisType``) and on current jax.
"""
from __future__ import annotations

import warnings

import jax

from repro import compat


def make_data_mesh(n_devices: int) -> jax.sharding.Mesh:
    """1-D ``("data",)`` mesh over the first ``n_devices`` local devices —
    the mesh shape ``DynasparseEngine(mesh=...)`` shards row-stripe bands
    over.  Raises when the host doesn't have that many devices (e.g. a
    snapshot produced on an 8-device host replayed on a 1-device box)."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    avail = len(jax.devices())
    if n_devices > avail:
        raise ValueError(
            f"requested a {n_devices}-device data mesh but only {avail} "
            f"device(s) are visible (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N to force "
            f"host devices for testing)")
    return compat.make_mesh((n_devices,), ("data",))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Deprecated shim over :func:`make_mesh_for_devices` — the ONE
    validated mesh factory.  The fixed 16×16 (/ 2×16×16) shapes stay for
    callers that still use them, but the device count is now checked up
    front: previously ``multi_pod=True`` on a single host built a 512-chip
    mesh shape that only blew up (or silently mis-sharded) at first use."""
    warnings.warn(
        "make_production_mesh is deprecated; use "
        "make_mesh_for_devices(n_devices, model_parallel=..., pods=...)",
        DeprecationWarning, stacklevel=2)
    n = 512 if multi_pod else 256
    avail = len(jax.devices())
    if n > avail:
        raise ValueError(
            f"make_production_mesh(multi_pod={multi_pod}) needs {n} devices "
            f"but only {avail} device(s) are visible"
            + (" — a multi-pod mesh cannot be built on a single host"
               if multi_pod else ""))
    return make_mesh_for_devices(n, model_parallel=16,
                                 pods=2 if multi_pod else 1)


def make_mesh_for_devices(n_devices: int, *, model_parallel: int = 1,
                          pods: int = 1) -> jax.sharding.Mesh:
    """Elastic variant: largest (pod, data, model) mesh for a device count
    (used by distributed.elastic after failures)."""
    if n_devices < 1 or model_parallel < 1 or pods < 1:
        raise ValueError(
            f"mesh factors must be positive: n_devices={n_devices}, "
            f"model_parallel={model_parallel}, pods={pods}")
    if n_devices % (model_parallel * pods) != 0:
        raise ValueError(
            f"n_devices={n_devices} is not divisible by "
            f"model_parallel*pods={model_parallel * pods} "
            f"(model_parallel={model_parallel}, pods={pods}); "
            f"cannot form a rectangular (pod, data, model) mesh")
    data = n_devices // (model_parallel * pods)
    if pods > 1:
        return compat.make_mesh((pods, data, model_parallel),
                                ("pod", "data", "model"))
    return compat.make_mesh((data, model_parallel), ("data", "model"))
