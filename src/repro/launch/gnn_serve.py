"""GNN serving driver: async micro-batched inference over a shared cache.

``PYTHONPATH=src python -m repro.launch.gnn_serve --dataset CO --model GCN
[--requests 64] [--max-batch 8] [--scale 0.05] [--cache-file plan.pkl]``

Fires a burst of synthetic same-graph requests through the ServingEngine
and prints a machine-readable stats line: latency percentiles, micro-batch
sizes, plan-cache hit rate and pallas launches per request.  With
``--cache-file`` the SharedPlanCache is loaded before serving (restart
skips re-analysis — observe packs/analyzes stay 0) and saved after.
"""
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="CO", help="Table-IV dataset id")
    ap.add_argument("--model", default="GCN")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=0.0)
    ap.add_argument("--scale", type=float, default=0.05,
                    help="graph scale factor (CPU-budget functional runs)")
    ap.add_argument("--drift-threshold", type=float, default=0.25)
    ap.add_argument("--literal", action="store_true",
                    help="literal Pallas dispatch (interpret mode on CPU)")
    ap.add_argument("--cache-file", default=None,
                    help="load the shared plan cache before serving, save "
                         "after (serving-restart persistence)")
    args = ap.parse_args()

    import numpy as np

    from repro.core import DynasparseEngine
    from repro.data.graphs import load_graph
    from repro.kernels import ops
    from repro.models import gnn
    from repro.serving import (ServingConfig, ServingEngine, SharedPlanCache,
                               SketchConfig)

    g = load_graph(args.dataset, scale=args.scale)
    in_dim = (g.features.shape[1] if hasattr(g.features, "shape")
              else g.stats.features)
    params = gnn.init_params(args.model, in_dim, g.stats.hidden,
                             g.stats.classes)

    cache = SharedPlanCache()
    if args.cache_file and os.path.exists(args.cache_file):
        print(f"[gnn_serve] loaded cache: {cache.load(args.cache_file)}")
    engine = DynasparseEngine(literal=args.literal, cache=cache)
    srv = ServingEngine(
        args.model, params, engine=engine,
        config=ServingConfig(
            max_batch=args.max_batch,
            max_delay_s=args.max_delay_ms * 1e-3,
            sketch=SketchConfig(threshold=args.drift_threshold)))
    srv.register_graph(args.dataset, g.adj)

    rng = np.random.default_rng(0)
    h0 = np.asarray(g.features_dense)
    reqs = []
    for _ in range(args.requests):
        noise = rng.normal(0, 0.01, size=h0.shape).astype(np.float32)
        reqs.append((args.dataset, (h0 + noise * (h0 != 0)).astype(np.float32)))

    ops.reset_pallas_call_count()
    try:
        outs = srv.serve(reqs)
    finally:
        srv.close()
    launches = ops.pallas_call_count()

    stats = srv.stats.as_dict()
    stats.update({
        "dataset": args.dataset, "model": args.model,
        "vertices": g.stats.vertices,
        "cache": cache.stats.as_dict(),
        "cache_bytes": cache.bytes_used,
        "plan_hit_rate": cache.stats.hit_rate,
        "pallas_launches_per_request": launches / max(1, len(outs)),
        "dispatch": srv.dispatch_stats(),
    })
    print("[gnn_serve] " + json.dumps(stats))

    if args.cache_file:
        print(f"[gnn_serve] saved cache: {cache.save(args.cache_file)}")


if __name__ == "__main__":
    main()
