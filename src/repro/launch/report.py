"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: Path) -> list[dict]:
    rows = []
    for p in sorted(out_dir.glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(rows: list[dict], mesh: str) -> str:
    out = ["| arch | shape | status | compile | peak/dev GB | per-chip GFLOPs"
           " | AG GB | AR GB | RS GB | A2A GB | CP GB |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                       f"— | — | — | — | — | — | — | — |")
            continue
        c = r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']}s | "
            f"{r['memory']['peak_per_device_gb']} | "
            f"{r['cost']['flops'] / 1e9:.0f} | "
            f"{c['all-gather'] / 1e9:.2f} | {c['all-reduce'] / 1e9:.2f} | "
            f"{c['reduce-scatter'] / 1e9:.2f} | "
            f"{c['all-to-all'] / 1e9:.2f} | "
            f"{c['collective-permute'] / 1e9:.2f} |")
    return "\n".join(out)


def roofline_table(rows: list[dict], mesh: str = "single") -> str:
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "MODEL_FLOPS | useful/HLO | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        t = r["roofline"]
        note = _note(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(t['compute_s'])} | "
            f"{fmt_t(t['memory_s'])} | {fmt_t(t['collective_s'])} | "
            f"**{t['dominant'].replace('_s', '')}** | "
            f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.3f} | "
            f"{note} |")
    return "\n".join(out)


def _note(r: dict) -> str:
    t = r["roofline"]
    dom = t["dominant"]
    if dom == "memory_s":
        return ("raise arithmetic intensity: larger per-chip tile / fewer "
                "remat passes / bf16 masters")
    if dom == "collective_s":
        return ("reduce cross-chip payload: overlap FSDP gathers, int8 "
                "grad-reduce, TP-local layouts")
    return "compute-bound: near roofline; MXU util is the lever"


def pick_hillclimb(rows: list[dict]) -> list[dict]:
    """worst roofline fraction / most collective-bound / most
    paper-representative (MoE = the dynamic-sparsity dispatch arch)."""
    ok = [r for r in rows if r["status"] == "ok" and r["mesh"] == "single"]

    def frac(r):
        t = r["roofline"]
        return t["compute_s"] / max(t["total_bound_s"], 1e-30)

    worst = min(ok, key=frac)
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["total_bound_s"], 1e-30))
    moe = [r for r in ok if r["arch"].startswith("deepseek-v2-236b")
           and r["shape"] == "train_4k"][0]
    picks = []
    for r in (worst, coll, moe):
        if r not in picks:
            picks.append(r)
    return picks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--what", default="all",
                    choices=("all", "dryrun", "roofline", "picks"))
    args = ap.parse_args()
    rows = load(Path(args.out))
    key = lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"]), r["mesh"])
    rows.sort(key=key)
    if args.what in ("all", "dryrun"):
        print("### Dry-run — single pod (16x16 = 256 chips)\n")
        print(dryrun_table(rows, "single"))
        print("\n### Dry-run — multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(rows, "multi"))
    if args.what in ("all", "roofline"):
        print("\n### Roofline (single pod, per-chip)\n")
        print(roofline_table(rows))
    if args.what in ("all", "picks"):
        print("\n### Hillclimb picks\n")
        for r in pick_hillclimb(rows):
            t = r["roofline"]
            print(f"- {r['arch']} x {r['shape']}: dominant={t['dominant']} "
                  f"compute={fmt_t(t['compute_s'])} "
                  f"bound={fmt_t(t['total_bound_s'])} "
                  f"fraction={t['compute_s'] / max(t['total_bound_s'], 1e-30):.3f}")


if __name__ == "__main__":
    main()
