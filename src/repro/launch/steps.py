"""Step functions lowered by the dry-run / executed by train.py & serve.py.

- ``train_step``: loss → grads → AdamW update (state donated).
- ``prefill_step``: full forward, last-position logits (inference prefill).
- ``serve_step``: one decode token against a deep KV cache (state donated).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.registry import ModelBundle, build_model, input_specs
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def make_train_step(bundle: ModelBundle, opt_cfg: AdamWConfig):
    """Grad-accumulation microbatching: the global batch is split into
    ``cfg.microbatches`` slices scanned sequentially, bounding the live
    activation-carry footprint to one microbatch (DESIGN.md §5: this is what
    makes 88-layer x 1M-token steps fit 16 GB/chip)."""
    mb = max(1, bundle.cfg.microbatches)

    def split(x):
        return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

    def train_step(state: dict, batch: dict):
        if mb == 1:
            loss, grads = jax.value_and_grad(bundle.loss)(
                state["params"], batch)
        else:
            micro = jax.tree.map(split, batch)

            def accum(carry, mb_batch):
                loss_acc, grads_acc = carry
                loss_i, grads_i = jax.value_and_grad(bundle.loss)(
                    state["params"], mb_batch)
                return (loss_acc + loss_i,
                        jax.tree.map(jnp.add, grads_acc, grads_i)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        new_params, new_opt, metrics = adamw_update(
            grads, state["opt"], state["params"], opt_cfg)
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics
    return train_step


def make_prefill_step(bundle: ModelBundle):
    from repro.models import lm as lm_lib

    def prefill_step(params, batch):
        if bundle.cfg.n_enc_layers:
            return bundle.forward(params, batch)[:, -1, :]
        return lm_lib.forward(params, batch, bundle.cfg, last_only=True)[:, 0]
    return prefill_step


def make_serve_step(bundle: ModelBundle):
    def serve_step(params, cache, batch):
        logits, new_cache = bundle.decode_step(
            params, cache, batch["tokens"], batch["pos"])
        return logits, new_cache
    return serve_step


def abstract_state(bundle: ModelBundle):
    """{"params", "opt"} as ShapeDtypeStructs (no allocation)."""
    params = bundle.abstract_params()
    mdt = jnp.dtype(bundle.cfg.opt_dtype)
    opt = jax.eval_shape(functools.partial(adamw_init, moment_dtype=mdt),
                         params)
    return {"params": params, "opt": opt}


def init_state(bundle: ModelBundle, seed: int = 0):
    params = bundle.init(jax.random.PRNGKey(seed))
    return {"params": params,
            "opt": adamw_init(params, jnp.dtype(bundle.cfg.opt_dtype))}
