"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds:
    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

``cost_analysis()`` provides global FLOPs/bytes.  Collective bytes are not in
cost_analysis — we parse the optimized HLO text and sum operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,512,128]{2,1,0}" possibly inside tuple "(" ... ")"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum RESULT-shape bytes of every collective op in the (SPMD,
    per-device) HLO.  Returns per-kind byte counts.

    Note: SPMD-partitioned HLO shapes are per-device, so these bytes are the
    per-chip collective payload — exactly what the ICI roofline term wants.
    ``start`` variants carry the shape; ``done`` variants are skipped to
    avoid double counting.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = <shape> <op>(...)" — find op token after '=' and shape
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = op.replace("-start", "")
        if op.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(shape_str)
    return out


# --------------------------------------------------------------------------
# Loop-aware HLO cost model.
#
# ``compiled.cost_analysis()`` counts every computation ONCE — including
# while-loop bodies, so a scanned 88-layer stack with 16 grad-accumulation
# microbatches is undercounted ~1400x.  We re-derive per-chip costs from the
# optimized HLO text: parse computations, recover scan trip counts from the
# loop-condition constants, and scale each instruction's FLOPs/bytes by the
# product of enclosing trip counts.  Bytes are post-fusion (one fusion = one
# op), which is exactly the HBM-traffic granularity the memory roofline
# wants.
# --------------------------------------------------------------------------
_COMP_RE = re.compile(r"^(ENTRY )?%?([\w.\-]+) \(.*\) -> .+ \{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w.\-]+) = (\([^)]*\)|\S+) ([\w\-]+)\((.*)$")


def _parse_computations(hlo: str):
    comps: dict[str, list[dict]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, shape_str, op, rest = mi.groups()
        comps[cur].append({"name": name, "shape": shape_str, "op": op,
                           "rest": rest, "line": line})
    return comps, entry


def _trip_count(line: str, cond_instrs: list[dict]) -> int:
    """XLA annotates scans with backend_config known_trip_count; fall back to
    the compare-constant in the loop condition."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    if m:
        return int(m.group(1))
    best = 1
    for ins in cond_instrs:
        if ins["op"] == "constant" and ins["shape"].startswith(("s32[]", "u32[]")):
            mc = re.search(r"constant\((\d+)\)", ins["line"])
            if mc:
                best = max(best, int(mc.group(1)))
    return best


def _dot_flops(ins: dict, shapes: dict[str, str]) -> float:
    """2 x |result| x K for dot ops (K = product of lhs contracting dims)."""
    out_elems = 1
    md = _SHAPE_RE.search(ins["shape"])
    if not md:
        return 0.0
    dims = md.group(2)
    for d in dims.split(",") if dims else []:
        out_elems *= int(d)
    mk = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins["line"])
    operands = re.findall(r"%([\w.\-]+)", ins["rest"])
    if not mk or not operands:
        return 0.0
    lhs_shape = shapes.get(operands[0], "")
    ml = _SHAPE_RE.search(lhs_shape)
    if not ml:
        return 0.0
    lhs_dims = [int(d) for d in ml.group(2).split(",") if d]
    k = 1
    for ci in mk.group(1).split(","):
        if ci != "" and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def hlo_cost(hlo: str) -> dict:
    """Loop-aware per-chip cost: flops, bytes, collective bytes by kind."""
    comps, entry = _parse_computations(hlo)
    shapes: dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins["name"]] = ins["shape"]

    # computation -> (trip, body) for each while op inside it
    children: dict[str, list[tuple[int, str]]] = {c: [] for c in comps}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins["op"] == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", ins["line"])
                mb = re.search(r"body=%?([\w.\-]+)", ins["line"])
                if mc and mb:
                    trips = _trip_count(ins["line"], comps.get(mc.group(1), []))
                    children[cname].append((trips, mb.group(1)))

    if entry is None:
        entry = next(iter(comps))

    mult: dict[str, float] = {}

    def visit(cname: str, m: float):
        mult[cname] = max(mult.get(cname, 0.0), m)
        for trips, body in children.get(cname, ()):
            visit(body, m * trips)

    visit(entry, 1.0)
    # called computations (fusions etc.) inherit caller's multiplier — we only
    # track whiles; fusion bodies are inline in bytes terms below.

    flops = 0.0
    bytes_acc = 0.0
    coll: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for cname, instrs in comps.items():
        m = mult.get(cname)
        if m is None:
            continue  # fusion sub-computations: costed at the call site
        for ins in instrs:
            op = ins["op"]
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "while", "bitcast", "copy-start", "copy-done"):
                continue
            out_b = _shape_bytes(ins["shape"])
            # HBM-traffic model: slicing ops touch only the slice, not the
            # whole operand; producers-without-reads touch only the result
            if op in ("dynamic-slice", "gather", "slice"):
                bytes_acc += m * 2 * out_b
            elif op == "dynamic-update-slice":
                ops_ = re.findall(r"%([\w.\-]+)", ins["rest"])
                upd = _shape_bytes(shapes.get(ops_[1], "")) if len(ops_) > 1 else out_b
                bytes_acc += m * 2 * upd
            elif op in ("broadcast", "iota"):
                bytes_acc += m * out_b
            else:
                in_b = sum(_shape_bytes(shapes.get(o, ""))
                           for o in re.findall(r"%([\w.\-]+)", ins["rest"]))
                bytes_acc += m * (out_b + in_b)
            if op == "dot":
                flops += m * _dot_flops(ins, shapes)
            elif op == "fusion":
                # dots inside fusions: cost the fused dot bodies
                mf = re.search(r"calls=%?([\w.\-]+)", ins["line"])
                if mf and mf.group(1) in comps:
                    for sub in comps[mf.group(1)]:
                        if sub["op"] == "dot":
                            flops += m * _dot_flops(sub, shapes)
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                coll[base] += int(m * _shape_bytes(ins["shape"]))
    return {"flops": flops, "bytes": bytes_acc, "collectives": coll}


def lowered_cost(fn, *args) -> dict:
    """Lower + compile a jit-wrapped callable and run :func:`hlo_cost` on
    the optimized HLO text.  The bridge between this module's static cost
    machinery and the measured runtime path: ``repro.core.calibrate`` uses
    it to cross-check its fitted memory bandwidth against the HLO-implied
    traffic of a reference GEMM (``CalibratedModel.roofline_bw_ratio``)."""
    return hlo_cost(fn.lower(*args).compile().as_text())


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float, n_chips: int) -> dict:
    """All inputs are PER-CHIP: ``compiled.cost_analysis()`` and
    ``compiled.as_text()`` describe the SPMD-partitioned (single-device)
    module, so its FLOPs/bytes/collective payloads are already per-chip —
    equivalent to the whole-program formulation HLO_total/(chips · peak).
    ``n_chips`` is kept for reporting."""
    t_compute = flops_per_chip / PEAK_FLOPS
    t_memory = bytes_per_chip / HBM_BW
    t_coll = coll_bytes_per_chip / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["total_bound_s"] = max(t_compute, t_memory, t_coll)
    terms["n_chips"] = n_chips
    return terms


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) — 'useful' training FLOPs.
    For inference shapes: 2·N·D per forward token (prefill) and 2·N_active
    per decoded token (decode)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def hlo_top_bytes(hlo: str, n: int = 15) -> list[tuple[float, str]]:
    """Debug: the N instructions contributing most HBM traffic (loop-scaled)."""
    comps, entry = _parse_computations(hlo)
    shapes = {i["name"]: i["shape"] for c in comps.values() for i in c}
    children: dict[str, list[tuple[int, str]]] = {c: [] for c in comps}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins["op"] == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", ins["line"])
                mb = re.search(r"body=%?([\w.\-]+)", ins["line"])
                if mc and mb:
                    trips = _trip_count(ins["line"], comps.get(mc.group(1), []))
                    children[cname].append((trips, mb.group(1)))
    mult: dict[str, float] = {}

    def visit(cname, m):
        mult[cname] = max(mult.get(cname, 0.0), m)
        for trips, body in children.get(cname, ()):
            visit(body, m * trips)

    visit(entry or next(iter(comps)), 1.0)
    out = []
    for cname, instrs in comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        for ins in instrs:
            op = ins["op"]
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "while", "bitcast", "copy-start", "copy-done"):
                continue
            ob = _shape_bytes(ins["shape"])
            if op in ("dynamic-slice", "gather", "slice"):
                b = 2 * ob
            elif op == "dynamic-update-slice":
                ops_ = re.findall(r"%([\w.\-]+)", ins["rest"])
                b = 2 * (_shape_bytes(shapes.get(ops_[1], ""))
                         if len(ops_) > 1 else ob)
            elif op in ("broadcast", "iota"):
                b = ob
            else:
                b = ob + sum(_shape_bytes(shapes.get(o, ""))
                             for o in re.findall(r"%([\w.\-]+)", ins["rest"]))
            out.append((m * b, f"x{m:g} {op} {ins['shape'][:60]} "
                        f"{ins['line'].strip()[:90]}"))
    out.sort(key=lambda t: -t[0])
    return out[:n]


def convert_traffic(hlo: str) -> float:
    """Bytes attributable to bf16<->f32 convert fusions (loop-scaled).

    The CPU backend emulates bf16 dots by converting operands to f32 —
    traffic that does NOT exist on TPU (the MXU consumes bf16 natively).
    Subtracting this gives the TPU-native memory term."""
    comps, entry = _parse_computations(hlo)
    shapes = {i["name"]: i["shape"] for c in comps.values() for i in c}
    children: dict[str, list[tuple[int, str]]] = {c: [] for c in comps}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins["op"] == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", ins["line"])
                mb = re.search(r"body=%?([\w.\-]+)", ins["line"])
                if mc and mb:
                    trips = _trip_count(ins["line"], comps.get(mc.group(1), []))
                    children[cname].append((trips, mb.group(1)))
    mult: dict[str, float] = {}

    def visit(cname, m):
        mult[cname] = max(mult.get(cname, 0.0), m)
        for trips, body in children.get(cname, ()):
            visit(body, m * trips)

    visit(entry or next(iter(comps)), 1.0)
    total = 0.0
    for cname, instrs in comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        for ins in instrs:
            if (("convert" in ins["name"] and ins["op"] == "fusion")
                    or ins["op"] == "convert"):
                ob = _shape_bytes(ins["shape"])
                ib = sum(_shape_bytes(shapes.get(o, ""))
                         for o in re.findall(r"%([\w.\-]+)", ins["rest"]))
                # TPU-native cost would be just the (narrow) operand read,
                # which remains counted by the consumer — charge all of it
                total += m * (ob + ib)
    return total
