"""Batched serving driver: prefill + decode loop with a paged-style cache.

``python -m repro.launch.serve --arch <id> [--batch B] [--gen N]``

Runs reduced configs end-to-end on CPU; the same serve_step is what the
dry-run lowers for decode_32k / long_500k on the production meshes.  The MoE
archs route their expert dispatch decision through the paper's analyzer
(``moe_dispatch_report``) — printed at startup as the integration evidence.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import ARCHS
    from repro.configs.reduced import reduce_config
    from repro.models.registry import build_model
    from repro.models.ffn import moe_dispatch_report

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_config(cfg)
    bundle = build_model(cfg)

    if cfg.ffn == "moe":
        rep = moe_dispatch_report(cfg, tokens=args.batch)
        print(f"[serve] MoE dispatch analyzer: density {rep['density']:.3f} "
              f"-> {rep['primitive']} (t_sparse {rep['t_sparse']:.2e}s vs "
              f"t_dense {rep['t_dense']:.2e}s)")

    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))

    decode = jax.jit(bundle.decode_step, donate_argnums=1)
    cache = bundle.init_cache(args.batch, max_len)

    # prefill token-by-token (reduced configs; a fused prefill kernel is the
    # natural next step and is exercised by the prefill_32k dry-run cells)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params,
                               cache,
                               jnp.asarray(prompts[:, t:t + 1], jnp.int32),
                               jnp.int32(t))
    toks = []
    for t in range(args.prompt_len, max_len):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks.append(np.asarray(nxt))
        logits, cache = decode(params, cache, nxt, jnp.int32(t))
    dt = time.time() - t0
    out = np.concatenate(toks, axis=1)
    total_toks = args.batch * max_len
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s incl. prefill)")
    print(f"[serve] sample: {out[0][:12].tolist()}")


if __name__ == "__main__":
    main()
