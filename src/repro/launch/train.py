"""Fault-tolerant training driver.

``python -m repro.launch.train --arch <id> [--steps N] [--ckpt-dir D]
[--mesh auto|single|multi] [--compress-grads] [--resume]``

Wires together: config → model bundle → mesh + shardings → AdamW train step
(jitted, donated) → TokenPipeline → CheckpointManager (async, atomic) →
FaultMonitor hooks.  On this CPU container it runs reduced configs end-to-end
(``--reduced``, default) — the same code path the dry-run lowers for the
production meshes.

XLA flags for the TPU target (collective overlap) are set in
``TPU_XLA_FLAGS`` below and exported by the real launcher; they are inert on
CPU.
"""
from __future__ import annotations

import argparse
import time

TPU_XLA_FLAGS = " ".join([
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--mesh", default="auto",
                    choices=("auto", "single", "multi"))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.configs import ARCHS
    from repro.configs.reduced import reduce_config
    from repro.data.lm import TokenPipeline
    from repro.distributed.fault import FaultMonitor
    from repro.distributed.sharding import (batch_shardings,
                                            params_shardings)
    from repro.launch.mesh import make_mesh_for_devices, make_production_mesh
    from repro.launch.steps import init_state, make_train_step
    from repro.models.registry import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.optim.compression import compress_decompress, ef_init

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_config(cfg)
    bundle = build_model(cfg)

    if args.mesh == "auto":
        n = len(jax.devices())
        mesh = make_mesh_for_devices(n, model_parallel=1 if n < 4 else 2)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=max(args.steps, 2))
    base_step = make_train_step(bundle, opt_cfg)

    if args.compress_grads:
        # wrap: quantize+EF the grads before the optimizer (see
        # optim/compression.py) — grads live inside base_step, so we rebuild
        # the step with a compressing loss-grad pipeline
        from repro.optim.adamw import adamw_update

        def base_step(state, batch):  # noqa: F811
            loss, grads = jax.value_and_grad(bundle.loss)(
                state["params"], batch)
            grads, ef = compress_decompress(grads, state["ef"])
            new_params, new_opt, metrics = adamw_update(
                grads, state["opt"], state["params"], opt_cfg)
            metrics = dict(metrics, loss=loss)
            return {"params": new_params, "opt": new_opt, "ef": ef}, metrics

    with mesh:
        state = init_state(bundle)
        if args.compress_grads:
            state["ef"] = ef_init(state["params"])
        state_sh = jax.tree.map(lambda x: x.sharding, jax.tree.map(
            lambda x: jax.device_put(x, jax.NamedSharding(
                mesh, jax.sharding.PartitionSpec())), state))
        # place real shardings for params/opt
        p_sh = params_shardings(state["params"], mesh)
        state = dict(state,
                     params=jax.device_put(state["params"], p_sh))

        ckpt = CheckpointManager(args.ckpt_dir, cfg=cfg)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            start, state = ckpt.restore(state)
            print(f"[train] resumed from step {start}")

        step_fn = jax.jit(base_step, donate_argnums=0)
        pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch,
                             seq_len=args.seq, start_step=start)
        monitor = FaultMonitor([f"host{i}" for i in range(
            max(1, jax.process_count()))])

        losses = []
        for step in range(start, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            monitor.heartbeat("host0", step_time=dt)
            losses.append(loss)
            print(f"[train] step {step:4d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms")
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save(step + 1, state)
        ckpt.wait()
        pipe.close()
        if len(losses) > 4:
            print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
                  f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
