"""Analytical performance model — paper Table I, parameterized per platform.

Two parameter sets ship:

- ``VCK5000`` reproduces the paper's numbers (f_AIE = 1 GHz, 32 AIE CCs x 4
  tiles, β = 8 MACs/cycle/tile; f_PL = 297 MHz, 8 ALU arrays with p = 8,
  q = 4; DDR 102.4 GB/s).  Used by the benchmark harness for Tables VI-VIII.
- ``TPUV5E`` re-parameterizes the same closed forms for the TPU target
  (MXU 197 TFLOP/s bf16 dense path; the sparse path skips zero *blocks*, so
  its α is block density and its per-MAC rate is the MXU rate discounted by a
  per-block dispatch overhead).  Used by the runtime to choose dense vs
  sparse dispatch on TPU.  **These constants are UNCALIBRATED fallback
  defaults** — the 0.85/0.70 block-skip efficiencies and the ~100 ns
  dispatch bubble are hand-tuned guesses, which is why the model is marked
  ``fallback=True``: engines constructed with it route through
  ``repro.core.calibrate`` on first plan (when calibration is enabled) so
  STQ/DTQ decisions track measured kernel timings on the backend
  ``repro.compat.backend_kind()`` reports, not the guesses.  ``VCK5000``
  stays analytical by design — it reproduces the paper's tables.

Closed forms (Table I):
    t_AIE   = m·n·d / (f_AIE · N_AIE · β)
    t_SpDMM = α_min · m·n·d / (f_PL · p·q)          [per ALU array]
    t_SpMM  = α_X · α_Y · m·n·d / (f_PL · p)        [per ALU array]
    t_ALU   = min(t_SpDMM, t_SpMM)
plus a memory term ``bytes / mem_bw`` (the paper's Ramulator-backed DDR
model reduced to a bandwidth bound): task time = max(compute, memory).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Primitive = Literal["GEMM", "SpDMM", "SpMM"]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    # dense engine (AIE array / MXU)
    f_dense: float            # Hz
    dense_macs_per_cycle: float   # N_AIE * beta  (whole dense engine)
    # sparse engine (one ALU array / block-skip kernel path)
    f_sparse: float           # Hz
    spdmm_macs_per_cycle: float   # p*q per sparse unit
    spmm_macs_per_cycle: float    # p per sparse unit
    n_sparse_units: int       # ALU arrays
    mem_bw: float             # bytes/s (DDR / HBM)
    bytes_per_elem: int = 4   # fp32 on VCK5000; bf16 = 2 on TPU
    # fixed per-task dispatch overhead (s) — runtime system + DMA setup
    dispatch_overhead: float = 0.0
    # TPU block-skip granularity (element-level on VCK5000 → block=1)
    skip_block: int = 1
    # provenance: ``fallback=True`` marks hand-tuned guess constants that a
    # runtime engine should replace with a measured ``CalibratedModel``
    # (repro.core.calibrate) when calibration is available; ``calibrated``
    # is set by the calibration subsystem on fitted models.
    fallback: bool = False
    calibrated: bool = False


# 32 AIE computation cores x 4 tiles = 128 tiles; beta = 8 MACs/cycle (fp32)
VCK5000 = HardwareModel(
    name="VCK5000",
    f_dense=1e9,
    dense_macs_per_cycle=128 * 8,
    f_sparse=297e6,
    spdmm_macs_per_cycle=8 * 4,
    spmm_macs_per_cycle=8,
    n_sparse_units=8,
    mem_bw=102.4e9,
    bytes_per_elem=4,
    dispatch_overhead=0.0,
    skip_block=1,
)

# Doubled-AIE scenario of Table VIII (384 of 400 tiles; memory unconstrained
# per the paper's assumption is handled by the caller scaling mem_bw).
VCK5000_384 = dataclasses.replace(
    VCK5000, name="VCK5000-384", dense_macs_per_cycle=256 * 8)

# TPU v5e: 197 TFLOP/s bf16 = 98.5e12 MAC/s on the dense path.  The sparse
# path is the block-skip Pallas kernel: same MXU rate on stored blocks, α is
# block density, and each stored block pays a dispatch bubble (~100 ns:
# scalar-prefetch DMA issue + grid step overheads).
#
# UNCALIBRATED FALLBACK: the 0.85/0.70 efficiency discounts and the 1e-7 s
# dispatch overhead were never measured — they are plausibility guesses.
# ``fallback=True`` routes engines built on this model through the
# calibration subsystem (repro.core.calibrate) so the Analyzer's STQ/DTQ
# mapping follows measured Pallas kernel timings wherever possible.
TPUV5E = HardwareModel(
    name="TPUv5e",
    f_dense=940e6,
    dense_macs_per_cycle=98.5e12 / 940e6,
    f_sparse=940e6,
    spdmm_macs_per_cycle=98.5e12 / 940e6 * 0.85,   # block-skip path efficiency
    spmm_macs_per_cycle=98.5e12 / 940e6 * 0.70,
    n_sparse_units=1,
    mem_bw=819e9,
    bytes_per_elem=2,
    dispatch_overhead=1e-7,
    skip_block=128,
    fallback=True,
)


def runtime_fallback(backend: str) -> HardwareModel:
    """Uncalibrated fallback model for a jax backend kind (the value
    ``repro.compat.backend_kind()`` reports: "tpu", "cpu", "gpu", ...).

    Every returned model carries ``fallback=True`` — the constants are
    starting guesses the calibration subsystem is expected to replace.  The
    non-TPU entries reuse the TPU closed forms with the name rebound so a
    ``CalibratedModel`` fitted on that backend is attributed honestly.
    """
    if backend == "tpu":
        return TPUV5E
    return dataclasses.replace(TPUV5E, name=f"{backend}-fallback")


@dataclasses.dataclass(frozen=True)
class TaskShape:
    """One task (Eq. 3): Z_ij = X_{i,:} · Y_{:,j}, X (m,n), Y (n,d)."""
    m: int
    n: int
    d: int
    alpha_x: float   # density of X_{i,:} (element or block per hw.skip_block)
    alpha_y: float   # density of Y_{:,j}

    @property
    def macs(self) -> int:
        return self.m * self.n * self.d


def t_dense(task: TaskShape, hw: HardwareModel) -> float:
    """GEMM on the dense engine (Table I col 1) + memory bound."""
    compute = task.macs / (hw.f_dense * hw.dense_macs_per_cycle)
    bytes_moved = (task.m * task.n + task.n * task.d + task.m * task.d
                   ) * hw.bytes_per_elem
    return max(compute, bytes_moved / hw.mem_bw) + hw.dispatch_overhead


def t_spdmm(task: TaskShape, hw: HardwareModel) -> float:
    """SpDMM on ONE sparse unit (Table I col 2) + memory bound."""
    a_min = min(task.alpha_x, task.alpha_y)
    compute = a_min * task.macs / (hw.f_sparse * hw.spdmm_macs_per_cycle)
    # loads: nonzeros of sparse operand (COO: 2 indices + value ≈ 3 words,
    # or the stored blocks on TPU) + the dense operand stripe + output
    if task.alpha_x <= task.alpha_y:
        sparse_elems, dense_elems = (task.alpha_x * task.m * task.n,
                                     task.n * task.d)
    else:
        sparse_elems, dense_elems = (task.alpha_y * task.n * task.d,
                                     task.m * task.n)
    bytes_moved = (3 * sparse_elems + dense_elems + task.m * task.d
                   ) * hw.bytes_per_elem
    return max(compute, bytes_moved / hw.mem_bw) + hw.dispatch_overhead


def t_spmm(task: TaskShape, hw: HardwareModel) -> float:
    """SpMM on ONE sparse unit (Table I col 3) + memory bound."""
    compute = (task.alpha_x * task.alpha_y * task.macs
               / (hw.f_sparse * hw.spmm_macs_per_cycle))
    bytes_moved = (3 * task.alpha_x * task.m * task.n
                   + 3 * task.alpha_y * task.n * task.d
                   + task.m * task.d) * hw.bytes_per_elem
    return max(compute, bytes_moved / hw.mem_bw) + hw.dispatch_overhead


def t_sparse(task: TaskShape, hw: HardwareModel) -> tuple[float, Primitive]:
    """Best sparse-engine time and which primitive achieves it (Eq. 5)."""
    a, b = t_spdmm(task, hw), t_spmm(task, hw)
    return (a, "SpDMM") if a <= b else (b, "SpMM")


def flops(task: TaskShape, primitive: Primitive) -> float:
    """FLOPs actually executed by the chosen primitive (Table V accounting).
    2 FLOPs per MAC."""
    if primitive == "GEMM":
        return 2.0 * task.macs
    if primitive == "SpDMM":
        return 2.0 * min(task.alpha_x, task.alpha_y) * task.macs
    return 2.0 * task.alpha_x * task.alpha_y * task.macs


def data_count(task: TaskShape, primitive: Primitive) -> float:
    """Elements loaded from memory by the chosen primitive (Table V)."""
    if primitive == "GEMM":
        return float(task.m * task.n + task.n * task.d)
    if primitive == "SpDMM":
        if task.alpha_x <= task.alpha_y:
            return float(task.alpha_x * task.m * task.n + task.n * task.d)
        return float(task.alpha_y * task.n * task.d + task.m * task.n)
    return float(task.alpha_x * task.m * task.n
                 + task.alpha_y * task.n * task.d)
