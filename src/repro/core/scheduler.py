"""Scheduler — Algorithm 4 lines 13-21.

Two roles:

1. ``simulate``: event-driven list scheduling of the two task queues onto the
   sparse units (8 ALU arrays on VCK5000) and the single dense engine (AIE
   array / MXU), exactly the paper's idle-unit pop loop.  Returns makespan and
   per-unit busy time — this is the cycle-estimate backend of the benchmark
   harness (the paper's own evaluation methodology: a perf-model-driven
   simulator with a DDR bandwidth bound, §IV-A).

2. ``execute_plan``: literal functional execution of a plan — each queue is
   drained with its real kernel (Pallas GEMM / SpDMM / SpMM) and the output
   tiles are assembled.  Used by tests to prove plan-execution equivalence
   and on TPU as the actual dispatch path.
"""
from __future__ import annotations

import dataclasses
import heapq

import jax.numpy as jnp
import numpy as np

from repro.core.partition import KernelPartition, Task
from repro.core.perfmodel import HardwareModel, flops, data_count
from repro.kernels import ops
from repro.kernels.formats import pack_blockcsr


@dataclasses.dataclass
class ScheduleReport:
    makespan: float                 # seconds (hardware execution time)
    t_sparse_busy: float            # Σ busy time over sparse units
    t_dense_busy: float             # busy time of the dense engine
    n_stq: int
    n_dtq: int
    n_spdmm: int
    n_spmm: int
    flops_executed: float
    flops_dense_equiv: float        # FLOPs had every task run as GEMM
    data_loaded: float              # elements (Table V "#Data")
    data_dense_equiv: float
    memory_time: float              # total bytes / BW (bandwidth bound)

    def merge(self, other: "ScheduleReport") -> "ScheduleReport":
        return ScheduleReport(
            makespan=self.makespan + other.makespan,
            t_sparse_busy=self.t_sparse_busy + other.t_sparse_busy,
            t_dense_busy=self.t_dense_busy + other.t_dense_busy,
            n_stq=self.n_stq + other.n_stq,
            n_dtq=self.n_dtq + other.n_dtq,
            n_spdmm=self.n_spdmm + other.n_spdmm,
            n_spmm=self.n_spmm + other.n_spmm,
            flops_executed=self.flops_executed + other.flops_executed,
            flops_dense_equiv=self.flops_dense_equiv + other.flops_dense_equiv,
            data_loaded=self.data_loaded + other.data_loaded,
            data_dense_equiv=self.data_dense_equiv + other.data_dense_equiv,
            memory_time=self.memory_time + other.memory_time,
        )


def simulate(stq: list[Task], dtq: list[Task], hw: HardwareModel) -> ScheduleReport:
    """List-schedule STQ onto ``hw.n_sparse_units`` ALU arrays and DTQ onto
    the dense engine; makespan = max(compute makespan, memory time)."""
    # sparse units: min-heap of available times
    sparse_free = [0.0] * hw.n_sparse_units
    heapq.heapify(sparse_free)
    sparse_busy = 0.0
    for task in stq:
        t0 = heapq.heappop(sparse_free)
        heapq.heappush(sparse_free, t0 + task.t_sparse)
        sparse_busy += task.t_sparse
    sparse_makespan = max(sparse_free) if sparse_free else 0.0

    dense_busy = sum(t.t_dense for t in dtq)

    # Both engines run concurrently (PL ∥ AIE): compute makespan is the max.
    compute_makespan = max(sparse_makespan, dense_busy)

    f_exec = sum(flops(t.shape, t.primitive) for t in stq + dtq)
    f_dense = sum(flops(t.shape, "GEMM") for t in stq + dtq)
    d_load = sum(data_count(t.shape, t.primitive) for t in stq + dtq)
    d_dense = sum(data_count(t.shape, "GEMM") for t in stq + dtq)
    memory_time = d_load * hw.bytes_per_elem / hw.mem_bw

    return ScheduleReport(
        makespan=max(compute_makespan, memory_time),
        t_sparse_busy=sparse_busy,
        t_dense_busy=dense_busy,
        n_stq=len(stq),
        n_dtq=len(dtq),
        n_spdmm=sum(1 for t in stq if t.primitive == "SpDMM"),
        n_spmm=sum(1 for t in stq if t.primitive == "SpMM"),
        flops_executed=f_exec,
        flops_dense_equiv=f_dense,
        data_loaded=d_load,
        data_dense_equiv=d_dense,
        memory_time=memory_time,
    )


def execute_plan(
    part: KernelPartition,
    stq: list[Task],
    dtq: list[Task],
    x,
    y,
    *,
    block: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Drain both queues with their REAL kernels and assemble Z.

    ``x``/``y`` are dense host/device matrices; sparse operands are packed
    per-stripe into BlockCSR on the fly (plan-time packing — §III-B
    preprocessing at task granularity).  Small-scale path: tests + TPU
    dispatch demonstration.
    """
    interpret = ops.default_interpret() if interpret is None else interpret
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    z = np.zeros((part.M, part.N), dtype=np.float32)
    tm, tn = part.tile_m, part.tile_n

    for task in dtq:  # dense engine: MXU GEMM
        xs = x[task.i * tm:(task.i + 1) * tm, :]
        ys = y[:, task.j * tn:(task.j + 1) * tn]
        z_tile = ops.gemm(xs, ys, bm=min(128, -(-xs.shape[0] // 8) * 8),
                          interpret=interpret, out_dtype=jnp.float32)
        z[task.i * tm: task.i * tm + xs.shape[0],
          task.j * tn: task.j * tn + ys.shape[1]] = np.asarray(z_tile)

    for task in stq:  # sparse engine: block-skip kernels
        xs = np.asarray(x[task.i * tm:(task.i + 1) * tm, :])
        ys = y[:, task.j * tn:(task.j + 1) * tn]
        x_bcsr = pack_blockcsr(xs, block)
        if task.primitive == "SpMM":
            y_bcsr = pack_blockcsr(np.asarray(ys), block)
            z_tile = ops.spmm(x_bcsr, y_bcsr, interpret=interpret)
        else:
            z_tile = ops.spdmm(x_bcsr, ys, bn=min(128, -(-ys.shape[1] // 8) * 8),
                               interpret=interpret)
        z[task.i * tm: task.i * tm + xs.shape[0],
          task.j * tn: task.j * tn + ys.shape[1]] = np.asarray(z_tile)

    return jnp.asarray(z)
