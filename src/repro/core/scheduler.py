"""Scheduler — Algorithm 4 lines 13-21.

Two roles:

1. ``simulate``: event-driven list scheduling of the two task queues onto the
   sparse units (8 ALU arrays on VCK5000) and the single dense engine (AIE
   array / MXU), exactly the paper's idle-unit pop loop.  Returns makespan and
   per-unit busy time — this is the cycle-estimate backend of the benchmark
   harness (the paper's own evaluation methodology: a perf-model-driven
   simulator with a DDR bandwidth bound, §IV-A).

2. ``execute_plan``: literal functional execution of a plan — each queue is
   drained with its real kernel (Pallas GEMM / SpDMM / SpMM) and the output
   tiles are assembled.  Used by tests to prove plan-execution equivalence
   and on TPU as the actual dispatch path.
"""
from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core.partition import KernelPartition, Task
from repro.core.perfmodel import HardwareModel, flops, data_count
from repro.kernels import ops
from repro.kernels.formats import (BlockCSR, first_visit_flags,
                                   pack_blockcsr, pair_block_triples)


@dataclasses.dataclass
class ScheduleReport:
    makespan: float                 # seconds (hardware execution time)
    t_sparse_busy: float            # Σ busy time over sparse units
    t_dense_busy: float             # busy time of the dense engine
    n_stq: int
    n_dtq: int
    n_spdmm: int
    n_spmm: int
    flops_executed: float
    flops_dense_equiv: float        # FLOPs had every task run as GEMM
    data_loaded: float              # elements (Table V "#Data")
    data_dense_equiv: float
    memory_time: float              # total bytes / BW (bandwidth bound)
    # sharded plans: one sub-report per mesh device (empty when unsharded).
    # The scalar fields above stay the combined view (makespan = slowest
    # device; busy/flops/data = totals) so existing consumers are unchanged.
    per_device: tuple = ()

    @classmethod
    def zero(cls) -> "ScheduleReport":
        """Identity element of ``merge`` — the report of zero kernels."""
        return cls(makespan=0.0, t_sparse_busy=0.0, t_dense_busy=0.0,
                   n_stq=0, n_dtq=0, n_spdmm=0, n_spmm=0,
                   flops_executed=0.0, flops_dense_equiv=0.0,
                   data_loaded=0.0, data_dense_equiv=0.0, memory_time=0.0)

    def merge(self, other: "ScheduleReport") -> "ScheduleReport":
        per_device: tuple = ()
        if self.per_device or other.per_device:
            a, b = list(self.per_device), list(other.per_device)
            n = max(len(a), len(b))
            a += [ScheduleReport.zero()] * (n - len(a))
            b += [ScheduleReport.zero()] * (n - len(b))
            per_device = tuple(x.merge(y) for x, y in zip(a, b))
        return ScheduleReport(
            makespan=self.makespan + other.makespan,
            t_sparse_busy=self.t_sparse_busy + other.t_sparse_busy,
            t_dense_busy=self.t_dense_busy + other.t_dense_busy,
            n_stq=self.n_stq + other.n_stq,
            n_dtq=self.n_dtq + other.n_dtq,
            n_spdmm=self.n_spdmm + other.n_spdmm,
            n_spmm=self.n_spmm + other.n_spmm,
            flops_executed=self.flops_executed + other.flops_executed,
            flops_dense_equiv=self.flops_dense_equiv + other.flops_dense_equiv,
            data_loaded=self.data_loaded + other.data_loaded,
            data_dense_equiv=self.data_dense_equiv + other.data_dense_equiv,
            memory_time=self.memory_time + other.memory_time,
            per_device=per_device,
        )

    def scaled(self, s: float) -> "ScheduleReport":
        """Cost fields scaled by ``s`` — the per-request attribution the
        serving layer uses for a micro-batch share.  The task / primitive
        counts describe the shared fused launches and are left intact."""
        return dataclasses.replace(
            self,
            makespan=self.makespan * s,
            t_sparse_busy=self.t_sparse_busy * s,
            t_dense_busy=self.t_dense_busy * s,
            flops_executed=self.flops_executed * s,
            flops_dense_equiv=self.flops_dense_equiv * s,
            data_loaded=self.data_loaded * s,
            data_dense_equiv=self.data_dense_equiv * s,
            memory_time=self.memory_time * s,
            per_device=tuple(r.scaled(s) for r in self.per_device),
        )


def simulate(stq: list[Task], dtq: list[Task], hw: HardwareModel) -> ScheduleReport:
    """List-schedule STQ onto ``hw.n_sparse_units`` ALU arrays and DTQ onto
    the dense engine; makespan = max(compute makespan, memory time)."""
    # sparse units: min-heap of available times
    sparse_free = [0.0] * hw.n_sparse_units
    heapq.heapify(sparse_free)
    sparse_busy = 0.0
    for task in stq:
        t0 = heapq.heappop(sparse_free)
        heapq.heappush(sparse_free, t0 + task.t_sparse)
        sparse_busy += task.t_sparse
    sparse_makespan = max(sparse_free) if sparse_free else 0.0

    dense_busy = sum(t.t_dense for t in dtq)

    # Both engines run concurrently (PL ∥ AIE): compute makespan is the max.
    compute_makespan = max(sparse_makespan, dense_busy)

    f_exec = sum(flops(t.shape, t.primitive) for t in stq + dtq)
    f_dense = sum(flops(t.shape, "GEMM") for t in stq + dtq)
    d_load = sum(data_count(t.shape, t.primitive) for t in stq + dtq)
    d_dense = sum(data_count(t.shape, "GEMM") for t in stq + dtq)
    memory_time = d_load * hw.bytes_per_elem / hw.mem_bw

    return ScheduleReport(
        makespan=max(compute_makespan, memory_time),
        t_sparse_busy=sparse_busy,
        t_dense_busy=dense_busy,
        n_stq=len(stq),
        n_dtq=len(dtq),
        n_spdmm=sum(1 for t in stq if t.primitive == "SpDMM"),
        n_spmm=sum(1 for t in stq if t.primitive == "SpMM"),
        flops_executed=f_exec,
        flops_dense_equiv=f_dense,
        data_loaded=d_load,
        data_dense_equiv=d_dense,
        memory_time=memory_time,
    )


def simulate_sharded(
    stq: list[Task],
    dtq: list[Task],
    placement,
    hws: list[HardwareModel],
) -> ScheduleReport:
    """Simulate a device-placed plan: each device runs its band's queues
    concurrently with every other device.  Combined makespan is the slowest
    device; busy times / flops / data are totals; ``per_device`` carries the
    per-device sub-reports for :attr:`EngineReport.by_device`."""
    if placement.n_devices != len(hws):
        raise ValueError(f"placement has {placement.n_devices} devices, "
                         f"got {len(hws)} hardware models")
    per_dev = []
    for d, hw in enumerate(hws):
        per_dev.append(simulate([t for t in stq if t.device == d],
                                [t for t in dtq if t.device == d], hw))
    combined = ScheduleReport.zero()
    for rep in per_dev:
        combined = combined.merge(rep)
    return dataclasses.replace(
        combined,
        makespan=max((r.makespan for r in per_dev), default=0.0),
        per_device=tuple(per_dev),
    )


def execute_plan(
    part: KernelPartition,
    stq: list[Task],
    dtq: list[Task],
    x,
    y,
    *,
    block: int = 8,
    interpret: bool | None = None,
    batched: bool = True,
    packed: dict[int, "BlockCSR"] | None = None,
    eps: float = 0.0,
) -> jnp.ndarray:
    """Drain both queues with their REAL kernels and assemble Z.

    ``x``/``y`` are dense host/device matrices.  ``batched=True`` (default)
    is the paper's whole-queue drain (Alg. 4 lines 13-21): the Dense Task
    Queue becomes ONE padded ``(n_tasks, tm, tn)`` GEMM launch, and the
    Sparse Task Queue's SpDMM / SpMM tasks are flattened into one entry /
    triple list each, driving a single fused kernel launch per primitive —
    O(primitives) pallas calls per kernel instead of O(tasks).  Each fused
    kernel's output index map scatters its tasks' tiles directly into ONE
    shared padded ``(M, N)`` canvas (aliased through the chain of
    primitives), so assembly is a single slice — no per-task scatter.

    ``packed`` optionally supplies pre-packed BlockCSR row-stripes of ``x``
    (index -> BlockCSR), the PlanCache's amortized §III-B preprocessing;
    missing stripes are packed on the fly.  ``batched=False`` keeps the
    original one-launch-per-task path for equivalence testing.

    ``x`` may be ``None`` on the batched path when ``packed`` covers every
    stripe the sparse queue touches AND the dense queue is empty — the
    engine's graph-scale mode, where the operand is never densified.
    """
    interpret = ops.default_interpret() if interpret is None else interpret
    if batched:
        return _execute_batched(part, stq, dtq, x, y, block=block,
                                interpret=interpret, packed=packed, eps=eps)
    return _execute_pertask(part, stq, dtq, x, y, block=block,
                            interpret=interpret, eps=eps, packed=packed)


def _execute_pertask(part, stq, dtq, x, y, *, block, interpret, eps=0.0,
                     packed=None):
    x = None if x is None else jnp.asarray(x)
    y = jnp.asarray(y)
    z = np.zeros((part.M, part.N), dtype=np.float32)
    tm, tn = part.tile_m, part.tile_n
    # device tiles are COLLECTED and pulled back in one transfer at the end:
    # a per-task np.asarray would force a device sync per launch, serializing
    # the queue drain on host<->device latency instead of compute
    pending: list[tuple[slice, slice, jnp.ndarray]] = []
    # host mirrors of the operands, materialized AT MOST ONCE if packing
    # needs them (one transfer instead of one sync per task)
    x_host = None
    y_host = None

    if dtq and x is None:
        raise ValueError("execute_plan: dense-queue tasks need the "
                         "densified x operand (got x=None)")
    for task in dtq:  # dense engine: MXU GEMM
        xs = x[task.i * tm:(task.i + 1) * tm, :]
        ys = y[:, task.j * tn:(task.j + 1) * tn]
        z_tile = ops.gemm(xs, ys, bm=min(128, -(-xs.shape[0] // 8) * 8),
                          interpret=interpret, out_dtype=jnp.float32)
        pending.append((slice(task.i * tm, task.i * tm + xs.shape[0]),
                        slice(task.j * tn, task.j * tn + ys.shape[1]),
                        z_tile))

    for task in stq:  # sparse engine: block-skip kernels
        if packed is not None and task.i in packed:
            x_bcsr = packed[task.i]
        elif x is None:
            raise ValueError(
                f"execute_plan: row-stripe {task.i} is missing from `packed` "
                "and no dense x was supplied to pack it from")
        else:
            if x_host is None:
                x_host = np.asarray(x)
            x_bcsr = pack_blockcsr(
                x_host[task.i * tm:(task.i + 1) * tm, :], block, eps=eps)
        mi = part.row_extent(task.i)
        ys = y[:, task.j * tn:(task.j + 1) * tn]
        if task.primitive == "SpMM":
            if y_host is None:
                y_host = np.asarray(y)
            y_bcsr = pack_blockcsr(
                y_host[:, task.j * tn:(task.j + 1) * tn], block, eps=eps)
            z_tile = ops.spmm(x_bcsr, y_bcsr, interpret=interpret)
        else:
            z_tile = ops.spdmm(x_bcsr, ys, bn=min(128, -(-ys.shape[1] // 8) * 8),
                               interpret=interpret)
        pending.append((slice(task.i * tm, task.i * tm + mi),
                        slice(task.j * tn, task.j * tn + ys.shape[1]),
                        z_tile))

    tiles = jax.device_get([t for _, _, t in pending])
    for (rs, cs, _), tile in zip(pending, tiles):
        z[rs, cs] = tile
    return jnp.asarray(z)


def _execute_batched(part, stq, dtq, x, y, *, block, interpret, packed=None,
                     eps=0.0):
    """Per-queue fused dispatch with in-place output assembly.

    ONE ``(M_pad, N_pad)`` canvas holds the final padded layout of the
    partition: row-stripe ``i`` occupies rows ``[i*SM, (i+1)*SM)`` and
    col-stripe ``j`` columns ``[j*SN, (j+1)*SN)``, where the slot sizes
    ``SM``/``SN`` equal the tile sizes (padded up only in the single-stripe
    case).  Each fused kernel scatters its tasks' tiles directly into that
    canvas through its output index map; the canvas is threaded through the
    primitives via output aliasing, so blocks a primitive doesn't touch
    keep what the previous primitive (or the zero init) left there.
    Assembly is ``canvas[:M, :N]`` — no per-task scatter loops.
    """
    tm, tn = part.tile_m, part.tile_n
    M, K, N = part.M, part.K, part.N
    nrt, nct = part.n_row_tiles, part.n_col_tiles
    B = block

    # The in-place index maps address the canvas in units of B-blocks (sparse
    # kernels) and 8-lane groups (GEMM tiles), so every interior slot
    # boundary i*SM / j*SN must be a multiple of lcm(B, 8).  The engine's
    # default geometry satisfies this; constructor-supplied tile sizes that
    # don't fall back to the equivalent per-task path (packed stripes are
    # reused there, so a graph-scale x=None call still works).
    slots = _dispatch.canvas_slots(part, B)
    if slots is None:
        return _execute_pertask(part, stq, dtq, x, y, block=B,
                                interpret=interpret, eps=eps, packed=packed)
    SM, SN = slots

    R = SM // B                      # block-rows per row-stripe slot
    C = SN // B                      # block-cols per col-stripe slot
    M_pad, N_pad = nrt * SM, nct * SN
    x = None if x is None else jnp.asarray(x)
    y = jnp.asarray(y)
    z = jnp.zeros((M_pad, N_pad), dtype=jnp.float32)

    spdmm_tasks = [t for t in stq if t.primitive != "SpMM"]
    spmm_tasks = [t for t in stq if t.primitive == "SpMM"]

    # pack (or fetch) the BlockCSR row-stripes the sparse queue needs
    stripes: dict[int, "BlockCSR"] = {}
    for i in sorted({t.i for t in spdmm_tasks} | {t.i for t in spmm_tasks}):
        if packed is not None and i in packed:
            stripes[i] = packed[i]
        else:
            if x is None:
                raise ValueError(
                    f"execute_plan: row-stripe {i} is missing from `packed` "
                    "and no dense x was supplied to pack it from")
            stripes[i] = pack_blockcsr(
                np.asarray(x[i * tm:(i + 1) * tm, :]), B, eps=eps)

    # ---------------- DTQ: one batched GEMM scattered into the canvas
    if dtq:
        if x is None:
            raise ValueError("execute_plan: dense-queue tasks need the "
                             "densified x operand (got x=None)")
        task_is = np.array([t.i for t in dtq], dtype=np.int32)
        task_js = np.array([t.j for t in dtq], dtype=np.int32)
        x_p = jnp.pad(x, ((0, M_pad - M), (0, 0)))
        y_p = jnp.pad(y, ((0, 0), (0, nct * tn - N))).reshape(K, nct, tn)
        if SN != tn:
            y_p = jnp.pad(y_p, ((0, 0), (0, 0), (0, SN - tn)))
        xs = x_p.reshape(nrt, SM, K)[task_is]
        ys = jnp.moveaxis(y_p, 1, 0)[task_js]
        z = ops.gemm_batch_scatter(xs, ys, task_is, task_js, z,
                                   interpret=interpret)

    # ---------------- STQ / SpDMM: one fused entry list
    if spdmm_tasks:
        ncb = -(-K // B)
        # Y with each col-stripe padded to SN columns, K padded to blocks
        y_pad = jnp.pad(y, ((0, ncb * B - K), (0, nct * tn - N)))
        y_f = jnp.pad(y_pad.reshape(ncb * B, nct, tn),
                      ((0, 0), (0, 0), (0, SN - tn))
                      ).reshape(ncb * B, nct * SN)
        offsets, a_pool = _dispatch._stripe_pool(spdmm_tasks, stripes)
        a_ids, y_rows, out_rows, out_cols, first = \
            _dispatch.spdmm_entry_arrays(spdmm_tasks, stripes, offsets, R)
        z = ops.spdmm_fused(
            a_pool, y_f, a_ids, y_rows, out_rows, out_cols, first,
            block_size=B, bn=SN, m_pad=M_pad, interpret=interpret, z=z)

    # ---------------- STQ / SpMM: one fused triple list
    if spmm_tasks:
        # ONE host pull of Y serves every col-stripe pack of this call — a
        # per-stripe np.asarray would sync the device once per stripe for
        # the same matrix the SpDMM section just laid out
        y_np = np.asarray(y)
        ystripes = {
            j: pack_blockcsr(y_np[:, j * tn:(j + 1) * tn], B, eps=eps)
            for j in sorted({t.j for t in spmm_tasks})}
        a_off: dict[int, int] = {}
        y_off: dict[int, int] = {}
        a_pool, y_pool = [], []
        off = 0
        for i in sorted({t.i for t in spmm_tasks}):
            a_off[i] = off
            a_pool.append(stripes[i].blocks[: stripes[i].nnzb])
            off += stripes[i].nnzb
        a_sent = off
        off = 0
        for j in sorted(ystripes):
            y_off[j] = off
            y_pool.append(ystripes[j].blocks[: ystripes[j].nnzb])
            off += ystripes[j].nnzb
        y_sent = off
        a_blocks = jnp.concatenate(
            a_pool + [jnp.zeros((1, B, B), a_pool[0].dtype)], axis=0)
        y_blocks = jnp.concatenate(
            y_pool + [jnp.zeros((1, B, B), y_pool[0].dtype)], axis=0)

        trip = []  # (out_row, out_col, a_id, y_id), per-task canvas regions
        for task in spmm_tasks:
            trip.extend(pair_block_triples(
                stripes[task.i], ystripes[task.j],
                a_sentinel=a_sent, y_sentinel=y_sent,
                a_offset=a_off[task.i], y_offset=y_off[task.j],
                base_row=task.i * R, base_col=task.j * C,
                n_row_blocks=-(-part.row_extent(task.i) // B),
                n_col_blocks=-(-part.col_extent(task.j) // B)))
        trip.sort()
        out_rows = np.array([t[0] for t in trip], dtype=np.int32)
        out_cols = np.array([t[1] for t in trip], dtype=np.int32)
        z = ops.spmm_fused(
            a_blocks, y_blocks,
            np.array([t[2] for t in trip], dtype=np.int32),
            np.array([t[3] for t in trip], dtype=np.int32),
            out_rows, out_cols,
            first_visit_flags(out_rows, out_cols),
            block_size=B, m_pad=M_pad, n_pad=N_pad,
            interpret=interpret, z=z)

    return z[:M, :N]
