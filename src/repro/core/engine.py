"""DynasparseEngine — the paper's accelerator as a composable JAX module.

One engine instance owns: the hardware model (VCK5000 for paper-fidelity
numbers, TPUv5e for deployment decisions), the 2-D partitioning geometry, the
Analyzer, the Scheduler and a structure-keyed :class:`PlanCache`.  Every GNN
kernel (and any other matmul routed through it, e.g. MoE expert dispatch)
goes through::

    z, report = engine.matmul(x, y, name="agg-l1")

which splits into two phases:

- ``plan``: (1) measure stripe densities, (2) build the task grid, (3) run
  the Analyzer (STQ/DTQ assignment via the perf model), (4) simulate the
  Scheduler for the hardware-time estimate.  For a ``SparseCOO`` operand the
  whole phase is cached on the sparsity structure — layer 2 and every
  subsequent inference request reuse the layer-1 plan (the paper's Alg. 4
  preprocessing amortized across layers, Dynasparse-style).

- ``execute``: compute the result — batched per-queue with the fused Pallas
  kernels when ``literal=True`` (tests/TPU; one launch per primitive, packed
  BlockCSR stripes served from the cache), or through the fastest
  functionally-equivalent path otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp
import numpy as np

from repro.core import analyzer as _analyzer
from repro.core import dispatch as _dispatch
from repro.core import primitives as prim
from repro.core import scheduler as _scheduler
from repro.core import shard_exec as _shard_exec
from repro.core import sparsity
from repro.kernels import ops as _ops
from repro.core.partition import choose_tile, make_tasks
from repro.core.perfmodel import VCK5000, HardwareModel
from repro.core.plancache import (KernelPlan, PlanCache, StructureEntry,
                                  coo_fingerprint)
from repro.core.primitives import SparseCOO
from repro.kernels.formats import pack_blockcsr_coo

Mode = Literal["dynamic", "sparse_only", "dense_only"]


@dataclasses.dataclass
class EngineReport:
    """Accumulated per-kernel schedule reports (one inference run)."""
    kernels: list[tuple[str, _scheduler.ScheduleReport]] = dataclasses.field(
        default_factory=list)
    # per-kernel recording used by the benchmark harness to replay the same
    # kernel sequence at full-scale geometry (see benchmarks/common.py)
    meta: list[dict] = dataclasses.field(default_factory=list)

    @property
    def total(self) -> _scheduler.ScheduleReport:
        if not self.kernels:
            return _scheduler.ScheduleReport.zero()
        rep = self.kernels[0][1]
        for _, r in self.kernels[1:]:
            rep = rep.merge(r)
        return rep

    @property
    def hardware_time(self) -> float:
        """End-to-end hardware execution time (kernels are sequential across
        layers — layer l+1 depends on layer l — but each kernel overlaps its
        two queues internally)."""
        return sum(r.makespan for _, r in self.kernels)

    def attributed(self, k: int) -> "EngineReport":
        """An even per-request share of a micro-batch report: every kernel's
        cost fields are divided by ``k`` (the batch's request count), so
        ``hardware_time``/FLOPs sum back to the batch total across its
        requests.  The kernel list and task counts still describe the shared
        fused launches.  ``k <= 1`` returns ``self`` — a batch of one IS the
        request."""
        if k <= 1:
            return self
        s = 1.0 / k
        return EngineReport(
            kernels=[(name, rep.scaled(s)) for name, rep in self.kernels],
            meta=list(self.meta))

    @property
    def by_device(self) -> list[_scheduler.ScheduleReport]:
        """Per-device totals of a (possibly) sharded run — one merged
        :class:`ScheduleReport` per mesh device, so heterogeneous device
        times are not silently summed into one scalar.  Kernels without a
        per-device breakdown (unsharded plans) are attributed to device 0;
        an unsharded run therefore returns ``[self.total]``."""
        out: list[_scheduler.ScheduleReport] = []
        for _, rep in self.kernels:
            per = list(rep.per_device) if rep.per_device else [rep]
            while len(out) < len(per):
                out.append(_scheduler.ScheduleReport.zero())
            for d, r in enumerate(per):
                out[d] = out[d].merge(r)
        return out


class DynasparseEngine:
    def __init__(
        self,
        hw: HardwareModel = VCK5000,
        *,
        tile_m: int | None = None,
        tile_n: int | None = None,
        mode: Mode = "dynamic",
        strategy: str = "balanced",
        literal: bool = False,
        block: int = 8,
        interpret: bool | None = None,
        eps: float = 0.0,
        batched: bool = True,
        cache: PlanCache | None = None,
        drift_threshold: float | None = None,
        sketch_rows: int = 256,
        calibration: object = "auto",
        mesh: object = None,
        operand_sharding: str = "halo",
        per_device_models: "list[HardwareModel] | None" = None,
        faults: object = None,
    ):
        self.hw = hw
        # optional repro.serving.faults.FaultInjector (duck-typed: anything
        # with .probe(site, detail)) consulted at the instrumented sites —
        # plan / lower / pack / execute and, on mesh engines, the sharded
        # path's shard_lower / shard_exec.  None (the default) keeps every
        # probe a no-op; the serving layer threads its configured injector
        # through here so chaos scenarios exercise the engine's real paths.
        self.faults = faults
        # 1-D ("data",) jax mesh → sharded plan/compile/execute: the
        # Analyzer's STQ/DTQ split becomes a two-level (device, queue)
        # placement and compiled kernels run under shard_map, one banded
        # program per device.  None = classic single-device engine (and a
        # size-1 mesh is the degenerate case of the SAME sharded path).
        if mesh is not None:
            names = tuple(getattr(mesh, "axis_names", ()))
            if names != ("data",):
                raise ValueError(
                    f"DynasparseEngine mesh must be 1-D with axis ('data',), "
                    f"got axes {names!r}")
        self.mesh = mesh
        # dense-operand distribution of the sharded executor: "halo" (the
        # default) ships each device only its OWNED block-rows plus the
        # halo its band reads (ppermute exchange inside the program);
        # "replicate" keeps the PR 8 full-replication layout — the bitwise
        # correctness oracle the halo path is gated against.
        if operand_sharding not in _shard_exec.OPERAND_SHARDINGS:
            raise ValueError(
                f"operand_sharding must be one of "
                f"{_shard_exec.OPERAND_SHARDINGS}, got {operand_sharding!r}")
        self.operand_sharding = operand_sharding
        # heterogeneous per-device cost models for band placement: the
        # band_partition DP already takes per-(device, stripe) costs, this
        # hook feeds it genuinely different models (e.g. two calibrated
        # device generations) instead of n_devices copies of ``hw``.
        if per_device_models is not None:
            if mesh is None:
                raise ValueError(
                    "per_device_models requires a mesh engine")
            n_mesh = int(np.prod(mesh.devices.shape))
            if len(per_device_models) != n_mesh:
                raise ValueError(
                    f"per_device_models must list one model per mesh device "
                    f"({n_mesh}), got {len(per_device_models)}")
            per_device_models = list(per_device_models)
        self.per_device_models = per_device_models
        # "auto": hw models marked ``fallback=True`` are replaced for
        # ANALYSIS by a measured CalibratedModel on first plan (lazy — the
        # sweep runs once per process and persists through self.cache);
        # "off": trust hw as given; a HardwareModel instance: use it.
        # Analytical models (VCK5000 & friends) are never calibrated away —
        # they reproduce the paper's tables by design.
        self.calibration = calibration
        self._hw_runtime: HardwareModel | None = None
        self.tile_m = tile_m
        self.tile_n = tile_n
        self.mode = mode
        self.strategy = strategy
        self.literal = literal
        self.block = block
        self.interpret = interpret
        self.eps = eps
        self.batched = batched
        self.cache = PlanCache() if cache is None else cache
        # density-drift revalidation of plan hits (the serving subsystem
        # enables this; None keeps the raw first-call amortization)
        self.drift_threshold = drift_threshold
        self.sketch_rows = sketch_rows
        self.report = EngineReport()
        # the plan behind the most recent matmul/plan call — lets the
        # whole-model compiler (models.gnn.compile_model) record each
        # kernel's plan without re-entering the cache/sketch machinery
        self.last_plan: KernelPlan | None = None

    @property
    def n_devices(self) -> int:
        """Mesh size (1 for classic single-device engines)."""
        return 1 if self.mesh is None else int(np.prod(self.mesh.devices.shape))

    def reset(self) -> None:
        """Clear the accumulated report.  The plan cache survives — it is
        keyed on operand structure, not on the inference run (serving path)."""
        self.report = EngineReport()

    # ------------------------------------------------------------------
    def runtime_hw(self) -> HardwareModel:
        """The model the Analyzer/Scheduler actually consult.

        Resolved once per engine: an explicit ``calibration`` model wins;
        ``"auto"`` calibrates ``fallback=True`` models through
        ``repro.core.calibrate`` (cache-first — a warm ``PlanCache`` or
        ``$REPRO_CALIBRATION_PATH`` snapshot means zero measurements) and
        leaves analytical models untouched; anything else keeps ``hw``.
        """
        if self._hw_runtime is None:
            hw = self.hw
            if isinstance(self.calibration, HardwareModel):
                hw = self.calibration
            elif self.calibration == "auto" and self.hw.fallback:
                from repro.core import calibrate as _calibrate
                hw = _calibrate.get_calibrated(
                    self.cache, self.hw, block=self.block,
                    interpret=self.interpret)
            self._hw_runtime = hw
        return self._hw_runtime

    def _geometry(self, M: int, N: int) -> tuple[int, int]:
        tm, tn = self.tile_m, self.tile_n
        if tm is None or tn is None:
            ctm, ctn = choose_tile(M, N)
            tm = tm or ctm
            tn = tn or ctn
        return min(tm, M), min(tn, N)

    def plan(self, x, y, name: str = "kernel") -> KernelPlan:
        """Preprocessing phase: densities → task grid → Analyzer → simulated
        schedule.  Cached on the sparsity structure for ``SparseCOO`` x."""
        if self.faults is not None:
            self.faults.probe("plan", detail=name)
        y = jnp.asarray(y)
        if isinstance(x, SparseCOO):
            M, K = x.shape
        else:
            x = jnp.asarray(x)
            M, K = x.shape
        N = y.shape[1]
        if y.shape[0] != K:
            raise ValueError(
                f"engine.matmul inner-dim mismatch: x is ({M}, {K}), "
                f"y is {tuple(y.shape)}")
        tm, tn = self._geometry(M, N)

        hw = self.runtime_hw()
        struct_key = None
        plan_key = None
        if isinstance(x, SparseCOO):
            struct_key = (coo_fingerprint(x), tm, self.eps)
            # keyed on the EFFECTIVE model's name: a calibrated name encodes
            # (base, backend, block, dtype), so plans decided under the
            # static guesses never shadow calibrated ones or vice versa
            plan_key = (struct_key, K, N, tn, self.mode, self.strategy,
                        hw.name)
            if self.mesh is not None:
                # mesh geometry is part of a placed plan's identity; classic
                # engines keep the historical key shape so their cached plans
                # are untouched by the sharding layer.  Heterogeneous device
                # models shift the band DP, so their names join the key.
                mesh_key = ("mesh", self.n_devices)
                if self.per_device_models is not None:
                    mesh_key += tuple(m.name for m in self.per_device_models)
                plan_key = plan_key + (mesh_key,)
            cached = self.cache.get_plan(plan_key)
            if cached is not None:
                if self.drift_threshold is None:
                    self.last_plan = cached
                    return cached
                # revalidate the first-call Y-density assumption with a
                # cheap row-sampled sketch; replan on drift (stale STQ/DTQ
                # assignment hazard — Dynasparse's re-decide-on-drift)
                sk = sparsity.sketch_col_density(
                    y, tn, max_rows=self.sketch_rows, eps=self.eps)
                drift = sparsity.density_drift(sk, cached.col_density)
                if drift <= self.drift_threshold:
                    self.last_plan = cached
                    return cached
                # a replanned hit amortized nothing: count it as a miss so
                # hit_rate stays an honest effectiveness signal under drift
                self.cache.stats.plan_hits -= 1
                self.cache.stats.plan_misses += 1
                self.cache.stats.replans += 1

        # (1) dynamic density measurement
        if isinstance(x, SparseCOO):
            row_d = self.cache.row_density(
                struct_key,
                lambda: x.row_stripe_density(tm, eps=self.eps))
        else:
            row_d = np.asarray(
                sparsity.stripe_density(x, tm, axis=0, eps=self.eps))
        col_d = np.asarray(
            sparsity.stripe_density(y, tn, axis=1, eps=self.eps))

        # (2) task grid
        part = make_tasks(name, M, K, N, row_d, col_d, tm, tn)

        # (3) analyzer — on the effective (possibly calibrated) model; mesh
        # engines additionally place contiguous stripe bands onto devices
        placement = None
        if self.mesh is not None:
            hws = (list(self.per_device_models)
                   if self.per_device_models is not None
                   else [hw] * self.n_devices)
            stq, dtq, placement = _analyzer.analyze_sharded(
                part, hws, strategy=self.strategy, mode=self.mode)
            rep = _scheduler.simulate_sharded(stq, dtq, placement, hws)
        else:
            if self.mode == "dynamic":
                stq, dtq = _analyzer.analyze_kernel(part, hw, self.strategy)
            elif self.mode == "sparse_only":
                stq, dtq = _analyzer.force_queue(part, hw, "STQ")
            else:
                stq, dtq = _analyzer.force_queue(part, hw, "DTQ")
            # (4) scheduler simulation → hardware-time estimate
            rep = _scheduler.simulate(stq, dtq, hw)
        plan = KernelPlan(part=part, stq=stq, dtq=dtq, report=rep,
                          row_density=np.asarray(row_d),
                          col_density=np.asarray(col_d),
                          struct_key=struct_key, placement=placement)
        if plan_key is not None:
            self.cache.put_plan(plan_key, plan)
        self.last_plan = plan
        return plan

    def _packed_structure(
            self, plan: KernelPlan,
            x: SparseCOO) -> tuple[tuple, StructureEntry]:
        """Packed BlockCSR row-stripes, cached per structure (one packing
        serves every kernel width and every request).  Stripes are packed
        straight from the COO triplets — no dense intermediate — so packing
        stays O(nnz + blocks) beyond toy scale."""
        tm = plan.part.tile_m
        nrt = plan.part.n_row_tiles
        K = x.shape[1]

        def _build() -> StructureEntry:
            if self.faults is not None:
                self.faults.probe("pack", detail=f"stripes:{nrt}")
            rows = np.asarray(x.rows)
            cols = np.asarray(x.cols)
            vals = np.asarray(x.vals)
            order = np.argsort(rows, kind="stable")
            rows, cols, vals = rows[order], cols[order], vals[order]
            bounds = np.searchsorted(rows, np.arange(nrt + 1) * tm)
            stripes = {}
            for i in range(nrt):
                lo, hi = bounds[i], bounds[i + 1]
                stripes[i] = pack_blockcsr_coo(
                    (plan.part.row_extent(i), K),
                    rows[lo:hi] - i * tm, cols[lo:hi], vals[lo:hi],
                    self.block, eps=self.eps)
            return StructureEntry(stripes=stripes)

        key = plan.struct_key + (self.block,)
        return key, self.cache.structure(key, _build)

    def _ensure_dense(self, key: tuple, entry: StructureEntry,
                      x: SparseCOO) -> jnp.ndarray:
        """Materialize the densified operand on first need (a plan routed
        tasks of this operand to the dense engine) and re-account its bytes;
        repeated requests then skip the host->device upload."""
        if entry.dense is None:
            entry.dense = jnp.asarray(x.todense())
            self.cache.recharge(PlanCache._STRUCT, key)
        return entry.dense

    def dispatch_for(self, plan: KernelPlan, x) -> "_dispatch.CompiledDispatch | None":
        """The plan's :class:`CompiledDispatch` (cached; lowered on first
        need), or ``None`` when the kernel is not compilable: non-literal /
        non-batched engines, uncacheable (dense X) operands, or canvas-
        misaligned geometry.  eps-thresholded SpMM plans compile too — the
        executor masks sub-eps Y blocks inside the traced program, so the
        pairing stays Y-structure-independent (``repro.core.dispatch``)."""
        if not (self.literal and self.batched):
            return None
        if self.mesh is not None:
            # mesh engines lower through sharded_dispatch_for — even at mesh
            # size 1, so the degenerate case exercises the shared shard path
            return None
        if not isinstance(x, SparseCOO) or plan.struct_key is None:
            return None
        if _dispatch.canvas_slots(plan.part, self.block) is None:
            return None
        _, entry = self._packed_structure(plan, x)
        digest = _dispatch.plan_digest(plan, self.block)
        return self.cache.dispatch(
            (plan.struct_key, digest),
            lambda: _dispatch.build_dispatch(
                plan.part, plan.stq, plan.dtq, entry.stripes,
                block=self.block, eps=self.eps, fingerprint=digest,
                faults=self.faults))

    def sharded_dispatch_for(
            self, plan: KernelPlan,
            x) -> "_shard_exec.ShardedDispatch | None":
        """The placed plan's :class:`~repro.core.shard_exec.ShardedDispatch`
        (cached; lowered on first need), or ``None`` when the kernel is not
        compilable — same decline conditions as :meth:`dispatch_for`, plus
        a missing placement (plan made by a non-mesh engine)."""
        if self.mesh is None or not (self.literal and self.batched):
            return None
        if not isinstance(x, SparseCOO) or plan.struct_key is None:
            return None
        if plan.placement is None:
            return None
        if _dispatch.canvas_slots(plan.part, self.block) is None:
            return None
        _, entry = self._packed_structure(plan, x)
        digest = _dispatch.plan_digest(plan, self.block)
        return self.cache.sharded_dispatch(
            (plan.struct_key, digest, self.n_devices, self.operand_sharding),
            lambda: _shard_exec.build_sharded_dispatch(
                plan.part, plan.stq, plan.dtq, entry.stripes, plan.placement,
                block=self.block, eps=self.eps, fingerprint=digest,
                operand_sharding=self.operand_sharding,
                faults=self.faults))

    def activation_dispatch_for(
            self, plan: KernelPlan, x, *, capacity=None,
            slack: float = 1.5,
            per_stripe: bool = True) -> "_dispatch.ActivationDispatch | None":
        """The plan's :class:`ActivationDispatch` — the capacity-padded
        block-skip route for a dense (activation-side) X — or ``None`` when
        the kernel should stay dense: non-literal/non-batched engines,
        sparse X (that is :meth:`dispatch_for`'s job), plans whose Analyzer
        routed every task to the dense engine (dense wins — a plain GEMM is
        the whole kernel), or canvas-misaligned geometry.

        ``capacity`` fixes the stored-block budget (an int for a uniform
        budget, or a per-stripe vector); by default it is measured from
        ``x`` (the warmup activation) with ``slack`` headroom —
        ``per_stripe=True`` sizes each stripe from ITS OWN warmup need
        (``dispatch.activation_budgets``), cutting padded-slot waste on
        skewed activations; ``per_stripe=False`` keeps the uniform
        max-need budget.  Descriptors are content-INDEPENDENT — cached on
        the plan digest (geometry + ordered assignment) and the budget, so
        every activation kernel with the same shape and task split shares
        one lowering and one trace."""
        if not (self.literal and self.batched):
            return None
        if isinstance(x, SparseCOO) or not plan.stq:
            return None
        if capacity is None:
            if per_stripe:
                capacity = _dispatch.activation_budgets(
                    x, plan.part, self.block, eps=self.eps, slack=slack)
            else:
                capacity = _dispatch.activation_capacity(
                    x, plan.part, self.block, eps=self.eps, slack=slack)
            if capacity is None:
                return None
        cap_key = (tuple(int(c) for c in np.asarray(capacity).ravel())
                   if np.ndim(capacity) else int(capacity))
        digest = _dispatch.plan_digest(plan, self.block)
        return self.cache.activation_dispatch(
            (digest, cap_key, self.eps),
            lambda: _dispatch.build_activation_dispatch(
                plan.part, plan.stq, plan.dtq, block=self.block,
                capacity=capacity, eps=self.eps, fingerprint=digest,
                faults=self.faults))

    def compiled_operands(
            self, plan: KernelPlan,
            x) -> "tuple[_dispatch.CompiledDispatch, jnp.ndarray | None] | None":
        """(dispatch, densified-x-or-None) for a plan, or ``None`` when the
        kernel is not compilable — the whole-model compiler's accessor."""
        d = self.dispatch_for(plan, x)
        if d is None:
            return None
        xd = None
        if d.needs_x:
            key, entry = self._packed_structure(plan, x)
            xd = self._ensure_dense(key, entry, x)
        return d, xd

    def sharded_operands(
            self, plan: KernelPlan,
            x) -> "tuple[_shard_exec.ShardedDispatch, jnp.ndarray | None] | None":
        """(sharded dispatch, densified-x-or-None) for a placed plan, or
        ``None`` when not compilable — the mesh-engine counterpart of
        :meth:`compiled_operands` used by the whole-model compiler."""
        sd = self.sharded_dispatch_for(plan, x)
        if sd is None:
            return None
        xd = None
        if sd.needs_x:
            key, entry = self._packed_structure(plan, x)
            xd = self._ensure_dense(key, entry, x)
        return sd, xd

    def execute(self, plan: KernelPlan, x, y) -> jnp.ndarray:
        """Functional result of a planned kernel (no re-analysis).

        Literal engines prefer the compiled dispatch: descriptor arrays are
        served from the cache and the whole kernel runs as ONE jitted call —
        zero per-request host work beyond dict lookups.  Kernels the compiler
        declines fall back to the eager batched (or per-task) path."""
        if self.faults is not None:
            self.faults.probe("execute", detail=plan.part.name)
        y = jnp.asarray(y)
        if self.literal:
            interpret = (_ops.default_interpret()
                         if self.interpret is None else self.interpret)
            if self.mesh is not None:
                spair = self.sharded_operands(plan, x)
                if spair is not None:
                    sd, xd = spair
                    return _shard_exec.execute_sharded(
                        sd, xd, y, mesh=self.mesh, interpret=interpret,
                        stats=self.cache.stats, faults=self.faults)
            pair = self.compiled_operands(plan, x)
            if pair is not None:
                d, xd = pair
                return _dispatch.execute_dispatch(
                    d, xd, y, interpret=interpret, stats=self.cache.stats)
            packed = None
            if isinstance(x, SparseCOO):
                if plan.struct_key is not None:
                    key, entry = self._packed_structure(plan, x)
                    packed = entry.stripes
                    # the densified operand is only needed by dense-engine
                    # tasks (batched GEMM gather) or the per-task path
                    if plan.dtq or not self.batched:
                        xd = self._ensure_dense(key, entry, x)
                    else:
                        xd = None
                else:
                    xd = x.todense()
            else:
                xd = x
            return _scheduler.execute_plan(
                plan.part, plan.stq, plan.dtq, xd, y,
                block=self.block, interpret=self.interpret,
                batched=self.batched, packed=packed, eps=self.eps)
        if isinstance(x, SparseCOO):
            return prim.spdmm_exec(x, y)
        return prim.gemm_exec(jnp.asarray(x), y)

    # ------------------------------------------------------------------
    def matmul(self, x, y, name: str = "kernel"):
        """Z = X · Y through the runtime system.  ``x`` may be ``SparseCOO``
        (graph adjacency) or a dense array; ``y`` is dense."""
        y = jnp.asarray(y)
        plan = self.plan(x, y, name=name)
        rep = plan.report
        self.report.kernels.append((name, rep))
        self.report.meta.append({
            "name": name,
            "M": plan.part.M, "K": plan.part.K, "N": plan.part.N,
            "x_is_adj": isinstance(x, SparseCOO) and x.tag == "adjacency",
            "alpha_x": float(np.mean(plan.row_density)),
            "alpha_y": float(np.mean(plan.col_density)),
        })
        z = self.execute(plan, x, y)
        return z, rep
