"""DynasparseEngine — the paper's accelerator as a composable JAX module.

One engine instance owns: the hardware model (VCK5000 for paper-fidelity
numbers, TPUv5e for deployment decisions), the 2-D partitioning geometry, the
Analyzer and the Scheduler.  Every GNN kernel (and any other matmul routed
through it, e.g. MoE expert dispatch) goes through::

    z, report = engine.matmul(x, y, name="agg-l1")

which (1) measures stripe densities on-device, (2) builds the task grid,
(3) runs the Analyzer (STQ/DTQ assignment via the perf model), (4) simulates
the Scheduler for the hardware-time estimate, and (5) computes the result —
literally per-queue with the Pallas kernels when ``literal=True`` (tests/TPU),
or through the fastest functionally-equivalent path otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp
import numpy as np

from repro.core import analyzer as _analyzer
from repro.core import primitives as prim
from repro.core import scheduler as _scheduler
from repro.core import sparsity
from repro.core.partition import choose_tile, make_tasks
from repro.core.perfmodel import VCK5000, HardwareModel
from repro.core.primitives import SparseCOO

Mode = Literal["dynamic", "sparse_only", "dense_only"]


@dataclasses.dataclass
class EngineReport:
    """Accumulated per-kernel schedule reports (one inference run)."""
    kernels: list[tuple[str, _scheduler.ScheduleReport]] = dataclasses.field(
        default_factory=list)
    # per-kernel recording used by the benchmark harness to replay the same
    # kernel sequence at full-scale geometry (see benchmarks/common.py)
    meta: list[dict] = dataclasses.field(default_factory=list)

    @property
    def total(self) -> _scheduler.ScheduleReport:
        rep = self.kernels[0][1]
        for _, r in self.kernels[1:]:
            rep = rep.merge(r)
        return rep

    @property
    def hardware_time(self) -> float:
        """End-to-end hardware execution time (kernels are sequential across
        layers — layer l+1 depends on layer l — but each kernel overlaps its
        two queues internally)."""
        return sum(r.makespan for _, r in self.kernels)


class DynasparseEngine:
    def __init__(
        self,
        hw: HardwareModel = VCK5000,
        *,
        tile_m: int | None = None,
        tile_n: int | None = None,
        mode: Mode = "dynamic",
        strategy: str = "balanced",
        literal: bool = False,
        block: int = 8,
        interpret: bool | None = None,
    ):
        self.hw = hw
        self.tile_m = tile_m
        self.tile_n = tile_n
        self.mode = mode
        self.strategy = strategy
        self.literal = literal
        self.block = block
        self.interpret = interpret
        self.report = EngineReport()

    def reset(self) -> None:
        self.report = EngineReport()

    # ------------------------------------------------------------------
    def matmul(self, x, y, name: str = "kernel"):
        """Z = X · Y through the runtime system.  ``x`` may be ``SparseCOO``
        (graph adjacency) or a dense array; ``y`` is dense."""
        y = jnp.asarray(y)
        if isinstance(x, SparseCOO):
            M, K = x.shape
        else:
            x = jnp.asarray(x)
            M, K = x.shape
        N = y.shape[1]

        tm, tn = self.tile_m, self.tile_n
        if tm is None or tn is None:
            ctm, ctn = choose_tile(M, N)
            tm = tm or ctm
            tn = tn or ctn
        tm, tn = min(tm, M), min(tn, N)

        # (1) dynamic density measurement
        if isinstance(x, SparseCOO):
            row_d = x.row_stripe_density(tm)
        else:
            row_d = np.asarray(sparsity.stripe_density(x, tm, axis=0))
        col_d = np.asarray(sparsity.stripe_density(y, tn, axis=1))

        # (2) task grid
        part = make_tasks(name, M, K, N, row_d, col_d, tm, tn)

        # (3) analyzer
        if self.mode == "dynamic":
            stq, dtq = _analyzer.analyze_kernel(part, self.hw, self.strategy)
        elif self.mode == "sparse_only":
            stq, dtq = _analyzer.force_queue(part, self.hw, "STQ")
        else:
            stq, dtq = _analyzer.force_queue(part, self.hw, "DTQ")

        # (4) scheduler simulation → hardware-time estimate
        rep = _scheduler.simulate(stq, dtq, self.hw)
        self.report.kernels.append((name, rep))
        self.report.meta.append({
            "name": name, "M": M, "K": K, "N": N,
            "x_is_adj": isinstance(x, SparseCOO) and x.tag == "adjacency",
            "alpha_x": float(np.mean(row_d)),
            "alpha_y": float(np.mean(col_d)),
        })

        # (5) functional result
        if self.literal:
            xd = x.todense() if isinstance(x, SparseCOO) else x
            z = _scheduler.execute_plan(part, stq, dtq, xd, y,
                                        block=self.block,
                                        interpret=self.interpret)
        elif isinstance(x, SparseCOO):
            z = prim.spdmm_exec(x, y)
        else:
            z = prim.gemm_exec(x, y)
        return z, rep
