"""PlanCache — amortized Analyzer/Scheduler preprocessing (plan/execute split).

The paper's runtime performs its preprocessing (density measurement, 2-D task
partitioning, Analyzer queue assignment, data-format packing) ONCE per kernel
on the APU and then drains the queues on the PL/AIE; Dynasparse amortizes the
same work across layers, and GraphAGILE compiles the kernel sequence ahead of
execution.  This module is the TPU-runtime analogue: everything derived from a
*static* operand's sparsity structure is computed once and reused across
layers and repeated inference calls (the serving path).

Two cache levels, held in ONE byte-accounted LRU store:

- **structure level** (keyed by the operand's sparsity fingerprint + tile
  geometry): row-stripe densities, and — for the literal execution path — the
  packed BlockCSR row-stripes (plus, lazily, the densified operand when a
  plan routes tasks to the dense engine).  Shared by every kernel that
  multiplies the same adjacency, regardless of the dense operand's width.

- **plan level** (structure key + full kernel geometry + engine mode): the
  task grid, STQ/DTQ assignment, and simulated ``ScheduleReport``.  A repeated
  kernel (same adjacency, same output width — e.g. every serving request)
  skips measurement, analysis and simulation entirely.

- **dispatch level** (structure key + plan digest): the plan lowered into a
  device-resident :class:`~repro.core.dispatch.CompiledDispatch` — sorted
  fused-kernel descriptor arrays and pooled block payloads — so steady-state
  execution is one jitted call with zero host descriptor construction.

- **activation-dispatch level** (plan digest + capacity + eps): the
  capacity-parameterized descriptor arrays of an activation-side (dense X)
  kernel (:class:`~repro.core.dispatch.ActivationDispatch`).  These are
  content-INDEPENDENT — the block payloads are packed on device per call —
  so, unlike every other level, they are shared across *different* operand
  contents with one geometry/assignment/budget.

Only kernels whose X operand is ``SparseCOO`` are cached: its structure is
static by construction (the graph), and the O(nnz) fingerprint is far cheaper
than the preprocessing it avoids.  Kernels with a dense X (activations) are
planned fresh every call.

A plan hit reuses the dense operand Y's column densities measured on the
FIRST call — the intended amortization (one assignment per kernel; Alg. 4 /
Dynasparse).  When the engine is constructed with a ``drift_threshold`` it
revalidates that assumption on every hit with a cheap activation-density
sketch and replans when the measured density has drifted (the serving
subsystem enables this by default; see ``repro.serving``).

Eviction is **byte-accounted LRU**: every entry is charged its deep array
payload (``nbytes_of``), the store evicts least-recently-used entries — plan
and structure entries alike — once ``max_bytes`` is exceeded (and keeps an
entry-count bound as a backstop).  ``repro.serving.cache.SharedPlanCache``
builds the process-wide, multi-graph, persistent variant on top.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, Iterator

import numpy as np

from repro.core.partition import KernelPartition, Task
from repro.core.scheduler import ScheduleReport
from repro.core.primitives import SparseCOO
from repro.kernels.formats import BlockCSR


def coo_fingerprint(x: SparseCOO) -> str:
    """Content digest of a COO matrix.  Values are included alongside the
    coordinates: the task assignment depends only on WHERE the nonzeros are,
    but the cached packed BlockCSR blocks carry the values themselves, so two
    matrices with one pattern and different values must not share an entry.

    Memoized on the instance so repeated calls with the same object are O(1);
    the memo is tagged with the component arrays' identities, so reassigning
    ``x.rows``/``x.cols``/``x.vals`` invalidates it (jax arrays themselves
    are immutable, so identity is sufficient)."""
    arr_ids = (id(x.rows), id(x.cols), id(x.vals))
    memo = getattr(x, "_plan_fp", None)
    if memo is not None and memo[0] == arr_ids:
        return memo[1]
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(np.asarray(x.rows)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(x.cols)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(x.vals)).tobytes())
    h.update(repr((tuple(x.shape), x.tag)).encode())
    fp = h.hexdigest()
    try:
        x._plan_fp = (arr_ids, fp)
    except Exception:  # frozen/slotted future variants: just recompute
        pass
    return fp


def key_mentions(key, fingerprint: str) -> bool:
    """True when ``fingerprint`` appears anywhere in a (nested) cache key.
    Every key that depends on an operand's content embeds its fingerprint
    digest verbatim — plan keys via ``struct_key``, structure/density keys
    directly, dispatch keys via ``struct_key`` — so a recursive scan finds
    all of a graph's entries without knowing each level's key layout."""
    if isinstance(key, tuple):
        return any(key_mentions(k, fingerprint) for k in key)
    return key == fingerprint


def nbytes_of(obj) -> int:
    """Deep byte size of a cache entry's array payload.

    Counts ndarray/jax buffers exactly (``.nbytes``) and charges a small flat
    constant per scalar/str/None so task lists are not free; containers and
    dataclasses are traversed recursively.  Python-object overhead is
    deliberately ignored — the arrays (packed blocks, densified operands,
    density vectors) dominate every real entry.
    """
    if obj is None:
        return 8
    if isinstance(obj, (bool, int, float, complex)):
        return 8
    if isinstance(obj, (str, bytes)):
        return len(obj)
    if isinstance(obj, np.ndarray):
        return int(obj.nbytes)
    nb = getattr(obj, "nbytes", None)
    if isinstance(nb, (int, np.integer)):       # jax.Array and friends
        return int(nb)
    if isinstance(obj, dict):
        return sum(nbytes_of(k) + nbytes_of(v) for k, v in obj.items())
    if isinstance(obj, (list, tuple, set, frozenset)):
        return sum(nbytes_of(v) for v in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(nbytes_of(getattr(obj, f.name))
                   for f in dataclasses.fields(obj))
    return 64  # unknown opaque object: flat charge


@dataclasses.dataclass
class CacheStats:
    plan_hits: int = 0
    plan_misses: int = 0
    struct_hits: int = 0
    struct_misses: int = 0
    packs: int = 0       # structure packing events (BlockCSR stripes)
    analyzes: int = 0    # structure density analyses
    replans: int = 0     # density-drift revalidations that re-planned
    evictions: int = 0   # entries dropped by LRU (bytes or count bound)
    bytes_evicted: int = 0
    invalidations: int = 0  # entries purged as stale (superseded graph)
    # compiled-dispatch level (the steady-state serving path): a build lowers
    # a plan into descriptor arrays ONCE; every later request is a hit plus a
    # jit trace-cache hit — zero host descriptor work.
    dispatch_builds: int = 0    # plan -> CompiledDispatch lowerings
    dispatch_hits: int = 0      # requests served from a cached dispatch
    trace_builds: int = 0       # end-to-end executor traces (jit misses)
    trace_cache_hits: int = 0   # executor calls that reused a trace
    # activation-side capacity route: descriptors are content-independent
    # (keyed on plan digest + stored-block budget), so one lowering serves
    # every activation kernel with the same geometry/assignment/budget.
    act_builds: int = 0         # plan -> ActivationDispatch lowerings
    act_hits: int = 0           # kernels served from a cached act dispatch
    # measured performance model (repro.core.calibrate): one microbenchmark
    # sweep per (device kind, block, dtype), persisted so a restarted
    # process replays ZERO measurements.
    calib_builds: int = 0       # CalibratedModel fits (compute() ran)
    calib_hits: int = 0         # models served from a cached calibration
    # snapshot robustness: unusable persistence artifacts (corrupt/truncated/
    # wrong-version plan-cache or calibration snapshots) that degraded to a
    # logged cold start instead of crashing the restart path
    snapshot_errors: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def hit_rate(self) -> float:
        total = self.plan_hits + self.plan_misses
        return self.plan_hits / total if total else 0.0


@dataclasses.dataclass
class KernelPlan:
    """Everything ``DynasparseEngine.execute`` needs, decoupled from planning.

    ``struct_key`` is set when the X operand is cacheable (static sparsity);
    it addresses the packed-stripe entry used by the literal dispatch path.
    ``placement`` is set by mesh engines (`analyze_sharded`): the contiguous
    row-stripe band each device owns; ``None`` on single-device plans.
    """
    part: KernelPartition
    stq: list[Task]
    dtq: list[Task]
    report: ScheduleReport
    row_density: np.ndarray
    col_density: np.ndarray
    struct_key: tuple | None = None
    placement: object | None = None   # core.partition.DevicePlacement


@dataclasses.dataclass
class StructureEntry:
    """Packed form of a static operand at one (tile_m, block, eps) geometry.

    ``dense`` is lazy: stripes are packed straight from the COO triplets
    (no dense intermediate — required beyond toy scale), and the densified
    operand is only materialized if a plan actually routes tasks of this
    operand to the dense engine (or the per-task path needs it)."""
    stripes: dict[int, BlockCSR]      # row-stripe index -> packed BlockCSR
    dense: object | None = None       # densified operand, device-resident


class PlanCache:
    """Structure-keyed, byte-accounted LRU cache of kernel plans and packed
    operands.

    ``capacity`` bounds the entry count (backstop); ``max_bytes`` bounds the
    summed deep array payload across ALL entry kinds — plans, density
    vectors and packed structures share one LRU order, so a cold graph's
    packed stripes are evicted before a hot graph's plans.
    """

    # entry-kind prefixes of the unified store
    _PLAN, _DENSITY, _STRUCT, _DISPATCH = "plan", "density", "struct", "dispatch"
    _ACT = "actdispatch"
    _CALIB = "calib"
    _SHARD = "sharddispatch"

    def __init__(self, capacity: int = 256, max_bytes: int | None = None):
        self.capacity = capacity
        self.max_bytes = max_bytes
        self._entries: OrderedDict[tuple, tuple[object, int]] = OrderedDict()
        self.bytes_used = 0
        self.stats = CacheStats()

    # ------------------------------------------------------------- helpers
    def _get(self, kind: str, key):
        k = (kind, key)
        if k in self._entries:
            self._entries.move_to_end(k)
            return self._entries[k][0]
        return None

    def _put(self, kind: str, key, value) -> None:
        k = (kind, key)
        nb = nbytes_of(value)
        if k in self._entries:
            self.bytes_used -= self._entries[k][1]
        self._entries[k] = (value, nb)
        self._entries.move_to_end(k)
        self.bytes_used += nb
        self._evict()

    def _evict(self) -> None:
        while len(self._entries) > self.capacity or (
                self.max_bytes is not None
                and self.bytes_used > self.max_bytes
                and len(self._entries) > 1):
            _, (_, nb) = self._entries.popitem(last=False)
            self.bytes_used -= nb
            self.stats.evictions += 1
            self.stats.bytes_evicted += nb

    def purge_fingerprint(self, fingerprint: str) -> int:
        """Drop every entry whose key embeds ``fingerprint`` (all levels:
        plans, densities, structures, dispatches).  The invalidation hook
        for content that is no longer reachable — e.g. a graph id was
        re-registered with different adjacency content and nothing else
        references the old content — so a later ``save`` cannot persist
        (and a ``load`` cannot resurrect) its stale compiled artifacts.
        Returns the number of entries purged."""
        doomed = [k for k in self._entries
                  if key_mentions(k[1], fingerprint)]
        for k in doomed:
            _, nb = self._entries.pop(k)
            self.bytes_used -= nb
            self.stats.invalidations += 1
        return len(doomed)

    def recharge(self, kind: str, key) -> None:
        """Re-measure an entry whose payload mutated in place (e.g. a
        ``StructureEntry`` whose lazy ``dense`` was just materialized)."""
        k = (kind, key)
        if k in self._entries:
            value, nb = self._entries[k]
            self.bytes_used -= nb
            new_nb = nbytes_of(value)
            self._entries[k] = (value, new_nb)
            self.bytes_used += new_nb
            self._evict()

    def __len__(self) -> int:
        return len(self._entries)

    def plan_count(self) -> int:
        """Number of cached plan-level entries.  The serving layer's
        single-plan gate: with ``pad_to_max_batch`` every registered graph
        contributes exactly one plan per distinct kernel geometry,
        regardless of traffic shape."""
        return sum(1 for (kind, _key) in self._entries if kind == self._PLAN)

    def items(self) -> Iterator[tuple[tuple, object]]:
        """(kind, key) -> value pairs in LRU order (persistence hook)."""
        for (kind, key), (value, _) in self._entries.items():
            yield (kind, key), value

    # ---------------------------------------------------------- plan level
    def get_plan(self, key: tuple) -> KernelPlan | None:
        plan = self._get(self._PLAN, key)
        if plan is None:
            self.stats.plan_misses += 1
        else:
            self.stats.plan_hits += 1
        return plan

    def put_plan(self, key: tuple, plan: KernelPlan) -> None:
        self._put(self._PLAN, key, plan)

    # ----------------------------------------------------- structure level
    def row_density(self, key: tuple,
                    compute: Callable[[], np.ndarray]) -> np.ndarray:
        """Get-or-compute the per-row-stripe densities of a static operand."""
        d = self._get(self._DENSITY, key)
        if d is not None:
            self.stats.struct_hits += 1
            return d
        self.stats.struct_misses += 1
        self.stats.analyzes += 1
        d = np.asarray(compute())
        self._put(self._DENSITY, key, d)
        return d

    def structure(self, key: tuple,
                  compute: Callable[[], StructureEntry]) -> StructureEntry:
        """Get-or-compute the packed BlockCSR-stripe form."""
        e = self._get(self._STRUCT, key)
        if e is not None:
            self.stats.struct_hits += 1
            return e
        self.stats.struct_misses += 1
        self.stats.packs += 1
        e = compute()
        self._put(self._STRUCT, key, e)
        return e

    # ------------------------------------------------------ dispatch level
    def dispatch(self, key: tuple, compute: Callable[[], object]):
        """Get-or-compute a :class:`~repro.core.dispatch.CompiledDispatch`.

        Keyed on (structure key, plan digest): a replan that lands on the
        same task assignment reuses the lowered descriptors; a changed
        assignment misses to a fresh build.  ``compute`` may return ``None``
        (unlowerable geometry) — never cached, so the caller's fallback
        decision is re-evaluated per plan, not remembered forever."""
        d = self._get(self._DISPATCH, key)
        if d is not None:
            self.stats.dispatch_hits += 1
            return d
        d = compute()
        if d is not None:
            self.stats.dispatch_builds += 1
            self._put(self._DISPATCH, key, d)
        return d

    def dispatch_count(self) -> int:
        """Number of cached compiled-dispatch entries (bench gate:
        ``dispatch_builds == plan_count()`` in steady state)."""
        return sum(1 for (kind, _k) in self._entries if kind == self._DISPATCH)

    def sharded_dispatch(self, key: tuple, compute: Callable[[], object]):
        """Get-or-compute a :class:`~repro.core.shard_exec.ShardedDispatch`.

        Keyed on (structure key, plan digest, device count, operand-sharding
        mode) — the digest of a placed plan already hashes the band layout
        and ownership geometry, the explicit device count keeps sharded
        entries key-separated from unsharded ones (so single- and
        multi-device plans of one graph coexist), and the mode keeps halo
        and replicated lowerings of one plan from shadowing each other.
        Counts into the shared dispatch_* counters: the bench invariants
        (``dispatch_builds == plans`` in steady state) hold per engine
        whether it shards or not."""
        d = self._get(self._SHARD, key)
        if d is not None:
            self.stats.dispatch_hits += 1
            return d
        d = compute()
        if d is not None:
            self.stats.dispatch_builds += 1
            self._put(self._SHARD, key, d)
        return d

    def sharded_count(self) -> int:
        """Number of cached sharded-dispatch entries."""
        return sum(1 for (kind, _k) in self._entries if kind == self._SHARD)

    def sharded_operand_bytes(self) -> dict:
        """Aggregate analytic dense-operand memory accounting over every
        cached sharded dispatch: owned / halo / replicated-fallback bytes
        (``ShardedDispatch.operand_bytes``) summed across entries, plus the
        replicated baseline those entries would have cost.  Surfaced by
        ``ServingEngine.dispatch_stats()``."""
        out = {"entries": 0, "owned_bytes": 0, "halo_bytes": 0,
               "fallback_bytes": 0, "replicated_bytes": 0}
        for (kind, _k), (value, _nb) in list(self._entries.items()):
            if kind != self._SHARD:
                continue
            ob = getattr(value, "operand_bytes", None)
            if not ob:
                continue
            out["entries"] += 1
            for f in ("owned_bytes", "halo_bytes", "fallback_bytes"):
                out[f] += int(ob.get(f, 0))
            out["replicated_bytes"] += (
                int(ob.get("replicated_per_device_bytes", 0))
                * int(getattr(value, "n_devices", 1)))
        return out

    def activation_dispatch(self, key: tuple, compute: Callable[[], object]):
        """Get-or-compute an
        :class:`~repro.core.dispatch.ActivationDispatch`.  Keyed on (plan
        digest, capacity, eps) — content-independent by construction, so
        activation kernels of different requests (and different layers with
        one geometry/assignment) share one descriptor lowering.  ``None``
        (unlowerable geometry) is never cached."""
        d = self._get(self._ACT, key)
        if d is not None:
            self.stats.act_hits += 1
            return d
        d = compute()
        if d is not None:
            self.stats.act_builds += 1
            self._put(self._ACT, key, d)
        return d

    def activation_count(self) -> int:
        """Number of cached activation-dispatch entries."""
        return sum(1 for (kind, _k) in self._entries if kind == self._ACT)

    # --------------------------------------------------- calibration level
    def calibration(self, key: tuple, compute: Callable[[], object]):
        """Get-or-compute a measured performance model
        (:class:`repro.core.calibrate.CalibratedModel`).  Keyed on
        (device kind, block, dtype[, base model]) — microbenchmark sweeps
        are the most expensive entry kind per byte, and a SharedPlanCache
        snapshot persists them so a restarted process replays zero
        measurements (``calib_builds == 0`` after load)."""
        m = self._get(self._CALIB, key)
        if m is not None:
            self.stats.calib_hits += 1
            return m
        m = compute()
        if m is not None:
            self.stats.calib_builds += 1
            self._put(self._CALIB, key, m)
        return m

    def calibration_count(self) -> int:
        """Number of cached calibration entries."""
        return sum(1 for (kind, _k) in self._entries if kind == self._CALIB)

    def clear(self) -> None:
        self._entries.clear()
        self.bytes_used = 0
        self.stats = CacheStats()
