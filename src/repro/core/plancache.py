"""PlanCache — amortized Analyzer/Scheduler preprocessing (plan/execute split).

The paper's runtime performs its preprocessing (density measurement, 2-D task
partitioning, Analyzer queue assignment, data-format packing) ONCE per kernel
on the APU and then drains the queues on the PL/AIE; Dynasparse amortizes the
same work across layers, and GraphAGILE compiles the kernel sequence ahead of
execution.  This module is the TPU-runtime analogue: everything derived from a
*static* operand's sparsity structure is computed once and reused across
layers and repeated inference calls (the serving path).

Two cache levels, both LRU-bounded:

- **structure level** (keyed by the operand's sparsity fingerprint + tile
  geometry): row-stripe densities, and — for the literal execution path — the
  densified operand plus its packed BlockCSR row-stripes.  Shared by every
  kernel that multiplies the same adjacency, regardless of the dense operand's
  width (layer-1 aggregation at hidden width and layer-2 aggregation at class
  width pack the adjacency exactly once).

- **plan level** (structure key + full kernel geometry + engine mode): the
  task grid, STQ/DTQ assignment, and simulated ``ScheduleReport``.  A repeated
  kernel (same adjacency, same output width — e.g. every serving request)
  skips measurement, analysis and simulation entirely.

Only kernels whose X operand is ``SparseCOO`` are cached: its structure is
static by construction (the graph), and the O(nnz) fingerprint is far cheaper
than the preprocessing it avoids.  Kernels with a dense X (activations) are
planned fresh every call.  Deliberate semantics of a plan hit: the DENSE
operand Y's column densities were measured on the FIRST call and are assumed
representative on reuse — that is exactly the amortization (one assignment
per kernel, queues drained without re-analysis; Alg. 4 / Dynasparse), and it
is what lets layer-2 aggregation and every serving request skip measurement.
If a workload's feature density shifts drastically between requests, drop the
cache (``engine.cache.clear()``) or use a fresh engine.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable

import numpy as np

from repro.core.partition import KernelPartition, Task
from repro.core.scheduler import ScheduleReport
from repro.core.primitives import SparseCOO
from repro.kernels.formats import BlockCSR


def coo_fingerprint(x: SparseCOO) -> str:
    """Content digest of a COO matrix.  Values are included alongside the
    coordinates: the task assignment depends only on WHERE the nonzeros are,
    but the cached packed BlockCSR blocks carry the values themselves, so two
    matrices with one pattern and different values must not share an entry.

    Memoized on the instance so repeated calls with the same object are O(1);
    the memo is tagged with the component arrays' identities, so reassigning
    ``x.rows``/``x.cols``/``x.vals`` invalidates it (jax arrays themselves
    are immutable, so identity is sufficient)."""
    arr_ids = (id(x.rows), id(x.cols), id(x.vals))
    memo = getattr(x, "_plan_fp", None)
    if memo is not None and memo[0] == arr_ids:
        return memo[1]
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(np.asarray(x.rows)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(x.cols)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(x.vals)).tobytes())
    h.update(repr((tuple(x.shape), x.tag)).encode())
    fp = h.hexdigest()
    try:
        x._plan_fp = (arr_ids, fp)
    except Exception:  # frozen/slotted future variants: just recompute
        pass
    return fp


@dataclasses.dataclass
class CacheStats:
    plan_hits: int = 0
    plan_misses: int = 0
    struct_hits: int = 0
    struct_misses: int = 0
    packs: int = 0       # structure packing events (densify + BlockCSR stripes)
    analyzes: int = 0    # structure density analyses

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class KernelPlan:
    """Everything ``DynasparseEngine.execute`` needs, decoupled from planning.

    ``struct_key`` is set when the X operand is cacheable (static sparsity);
    it addresses the packed-stripe entry used by the literal dispatch path.
    """
    part: KernelPartition
    stq: list[Task]
    dtq: list[Task]
    report: ScheduleReport
    row_density: np.ndarray
    col_density: np.ndarray
    struct_key: tuple | None = None


@dataclasses.dataclass
class StructureEntry:
    """Packed form of a static operand at one (tile_m, block, eps) geometry."""
    dense: object                     # densified operand, device-resident
    stripes: dict[int, BlockCSR]      # row-stripe index -> packed BlockCSR


class PlanCache:
    """Structure-keyed LRU cache of kernel plans and packed operands."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._plans: OrderedDict[tuple, KernelPlan] = OrderedDict()
        self._densities: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._structs: OrderedDict[tuple, StructureEntry] = OrderedDict()
        self.stats = CacheStats()

    # ------------------------------------------------------------- helpers
    def _get(self, store: OrderedDict, key):
        if key in store:
            store.move_to_end(key)
            return store[key]
        return None

    def _put(self, store: OrderedDict, key, value):
        store[key] = value
        store.move_to_end(key)
        while len(store) > self.capacity:
            store.popitem(last=False)

    # ---------------------------------------------------------- plan level
    def get_plan(self, key: tuple) -> KernelPlan | None:
        plan = self._get(self._plans, key)
        if plan is None:
            self.stats.plan_misses += 1
        else:
            self.stats.plan_hits += 1
        return plan

    def put_plan(self, key: tuple, plan: KernelPlan) -> None:
        self._put(self._plans, key, plan)

    # ----------------------------------------------------- structure level
    def row_density(self, key: tuple,
                    compute: Callable[[], np.ndarray]) -> np.ndarray:
        """Get-or-compute the per-row-stripe densities of a static operand."""
        d = self._get(self._densities, key)
        if d is not None:
            self.stats.struct_hits += 1
            return d
        self.stats.struct_misses += 1
        self.stats.analyzes += 1
        d = np.asarray(compute())
        self._put(self._densities, key, d)
        return d

    def structure(self, key: tuple,
                  compute: Callable[[], StructureEntry]) -> StructureEntry:
        """Get-or-compute the packed (dense + BlockCSR stripes) form."""
        e = self._get(self._structs, key)
        if e is not None:
            self.stats.struct_hits += 1
            return e
        self.stats.struct_misses += 1
        self.stats.packs += 1
        e = compute()
        self._put(self._structs, key, e)
        return e

    def clear(self) -> None:
        self._plans.clear()
        self._densities.clear()
        self._structs.clear()
        self.stats = CacheStats()
