"""2-D data partitioning (paper §III-B) and task construction (Eq. 2/3).

A *kernel* is one matmul ``Z = X · Y`` (feature aggregation ``A·H`` or feature
transformation ``H·W``).  It is decomposed into independent *tasks*, one per
output partition ``Z_ij = X_{i,:} · Y_{:,j}`` — the unit the runtime system
schedules onto the dense or sparse engine.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.perfmodel import Primitive, TaskShape


@dataclasses.dataclass
class Task:
    kernel: str
    i: int                    # output row-tile index
    j: int                    # output col-tile index
    shape: TaskShape          # m, n, d + stripe densities
    # filled by the analyzer:
    primitive: Primitive | None = None
    queue: str | None = None        # "STQ" | "DTQ"
    t_dense: float = 0.0
    t_sparse: float = 0.0
    _sparse_prim: Primitive = "SpDMM"   # best sparse primitive (analyzer)

    @property
    def t_assigned(self) -> float:
        return self.t_sparse if self.queue == "STQ" else self.t_dense


@dataclasses.dataclass
class KernelPartition:
    """All tasks of one kernel, plus tile geometry for (re)assembly."""
    name: str
    M: int
    K: int
    N: int
    tile_m: int
    tile_n: int
    tasks: list[Task]

    @property
    def n_row_tiles(self) -> int:
        return -(-self.M // self.tile_m)

    @property
    def n_col_tiles(self) -> int:
        return -(-self.N // self.tile_n)

    def row_extent(self, i: int) -> int:
        """Logical row count of row-tile ``i`` (ragged tail aware)."""
        return min(self.tile_m, self.M - i * self.tile_m)

    def col_extent(self, j: int) -> int:
        """Logical column count of col-tile ``j`` (ragged tail aware)."""
        return min(self.tile_n, self.N - j * self.tile_n)


def make_tasks(
    name: str,
    M: int, K: int, N: int,
    row_density: Sequence[float],
    col_density: Sequence[float],
    tile_m: int,
    tile_n: int,
) -> KernelPartition:
    """Build the task grid from per-stripe densities.

    ``row_density[i]`` is α(X_{i,:}) over the FULL contraction dim (the
    concatenation of X_{ik} over k, Eq. 3); ``col_density[j]`` is α(Y_{:,j}).
    """
    nrt, nct = -(-M // tile_m), -(-N // tile_n)
    assert len(row_density) == nrt, (len(row_density), nrt)
    assert len(col_density) == nct, (len(col_density), nct)
    tasks = []
    for i in range(nrt):
        m = min(tile_m, M - i * tile_m)
        for j in range(nct):
            d = min(tile_n, N - j * tile_n)
            tasks.append(Task(
                kernel=name, i=i, j=j,
                shape=TaskShape(m=m, n=K, d=d,
                                alpha_x=float(row_density[i]),
                                alpha_y=float(col_density[j])),
            ))
    return KernelPartition(name=name, M=M, K=K, N=N,
                           tile_m=tile_m, tile_n=tile_n, tasks=tasks)


def choose_tile(M: int, N: int, target_tiles: int = 64,
                minimum: int = 128) -> tuple[int, int]:
    """Pick tile sizes giving roughly ``target_tiles`` tasks.

    Mirrors the paper's preprocessing choice: partitions must fit on-chip
    memory but be numerous enough to load-balance 8 ALU arrays + AIE.
    """
    def pick(dim):
        t = max(minimum, int(np.ceil(dim / np.sqrt(target_tiles))))
        # round up to a multiple of 128 for MXU alignment
        return -(-t // 128) * 128

    return min(pick(M), -(-M // 128) * 128), min(pick(N), -(-N // 128) * 128)
