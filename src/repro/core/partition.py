"""2-D data partitioning (paper §III-B) and task construction (Eq. 2/3).

A *kernel* is one matmul ``Z = X · Y`` (feature aggregation ``A·H`` or feature
transformation ``H·W``).  It is decomposed into independent *tasks*, one per
output partition ``Z_ij = X_{i,:} · Y_{:,j}`` — the unit the runtime system
schedules onto the dense or sparse engine.

Placement (multi-device): on a mesh engine the Analyzer's queue assignment
becomes a TWO-level decision ``(device, queue)`` — each device owns a
contiguous band of row-stripes (:class:`DevicePlacement`, min-makespan over
the per-device hardware models via :func:`band_partition`), and within its
band the usual STQ/DTQ split applies.  This is the paper's PL/AIE
heterogeneous split re-expressed across chips (H-GCN's density-driven
subgraph placement at mesh scope).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Sequence

import numpy as np

from repro.core.perfmodel import Primitive, TaskShape


@dataclasses.dataclass
class Task:
    kernel: str
    i: int                    # output row-tile index
    j: int                    # output col-tile index
    shape: TaskShape          # m, n, d + stripe densities
    # filled by the analyzer:
    primitive: Primitive | None = None
    queue: str | None = None        # "STQ" | "DTQ"
    t_dense: float = 0.0
    t_sparse: float = 0.0
    device: int = 0                 # mesh placement (analyze_sharded)
    _sparse_prim: Primitive = "SpDMM"   # best sparse primitive (analyzer)

    @property
    def t_assigned(self) -> float:
        return self.t_sparse if self.queue == "STQ" else self.t_dense


@dataclasses.dataclass
class KernelPartition:
    """All tasks of one kernel, plus tile geometry for (re)assembly."""
    name: str
    M: int
    K: int
    N: int
    tile_m: int
    tile_n: int
    tasks: list[Task]

    @property
    def n_row_tiles(self) -> int:
        return -(-self.M // self.tile_m)

    @property
    def n_col_tiles(self) -> int:
        return -(-self.N // self.tile_n)

    def row_extent(self, i: int) -> int:
        """Logical row count of row-tile ``i`` (ragged tail aware)."""
        return min(self.tile_m, self.M - i * self.tile_m)

    def col_extent(self, j: int) -> int:
        """Logical column count of col-tile ``j`` (ragged tail aware)."""
        return min(self.tile_n, self.N - j * self.tile_n)


@dataclasses.dataclass(frozen=True)
class DevicePlacement:
    """Assignment of contiguous row-stripe bands to mesh devices.

    ``band_starts`` has ``n_devices + 1`` monotone entries with
    ``band_starts[0] == 0`` and ``band_starts[-1] == n_row_tiles``; device
    ``d`` owns stripes ``[band_starts[d], band_starts[d+1])``.  Bands may be
    empty (more devices than stripes).
    """
    n_devices: int
    band_starts: tuple[int, ...]

    def __post_init__(self):
        bs = self.band_starts
        if len(bs) != self.n_devices + 1 or bs[0] != 0:
            raise ValueError(f"malformed band_starts {bs} for "
                             f"{self.n_devices} devices")
        if any(bs[d] > bs[d + 1] for d in range(self.n_devices)):
            raise ValueError(f"band_starts must be monotone, got {bs}")

    @property
    def n_row_tiles(self) -> int:
        return self.band_starts[-1]

    def device_of(self, stripe: int) -> int:
        if not 0 <= stripe < self.n_row_tiles:
            raise ValueError(f"stripe {stripe} outside [0, {self.n_row_tiles})")
        return bisect.bisect_right(self.band_starts, stripe) - 1

    def stripes_of(self, device: int) -> range:
        return range(self.band_starts[device], self.band_starts[device + 1])

    def band_sizes(self) -> tuple[int, ...]:
        bs = self.band_starts
        return tuple(bs[d + 1] - bs[d] for d in range(self.n_devices))


def band_partition(loads: np.ndarray, n_devices: int) -> tuple[int, ...]:
    """Min-makespan contiguous partition of stripes into device bands.

    ``loads[d, s]`` is the cost of stripe ``s`` when placed on device ``d``
    (devices may run heterogeneous :class:`CalibratedModel`\\ s, so the cost
    of the same stripe differs per device).  Exact DP:
    ``f[d][b] = min_a max(f[d-1][a], sum(loads[d, a:b]))``, O(D·S²).
    Returns ``band_starts`` of length ``n_devices + 1``.
    """
    loads = np.asarray(loads, dtype=np.float64)
    if loads.ndim != 2 or loads.shape[0] != n_devices:
        raise ValueError(f"loads must be (n_devices, n_stripes), got "
                         f"{loads.shape} for {n_devices} devices")
    S = loads.shape[1]
    # prefix[d, b] = sum of loads[d, :b]
    prefix = np.concatenate(
        [np.zeros((n_devices, 1)), np.cumsum(loads, axis=1)], axis=1)
    f = prefix[0].copy()               # device 0 takes stripes [0, b)
    back = np.zeros((n_devices, S + 1), dtype=np.int64)
    for d in range(1, n_devices):
        nf = np.empty(S + 1)
        for b in range(S + 1):
            band = prefix[d, b] - prefix[d, : b + 1]     # cost of [a, b) on d
            cand = np.maximum(f[: b + 1], band)
            a = int(np.argmin(cand))
            nf[b] = cand[a]
            back[d, b] = a
        f = nf
    starts = [S]
    for d in range(n_devices - 1, 0, -1):
        starts.append(int(back[d, starts[-1]]))
    starts.append(0)
    return tuple(reversed(starts))


def make_tasks(
    name: str,
    M: int, K: int, N: int,
    row_density: Sequence[float],
    col_density: Sequence[float],
    tile_m: int,
    tile_n: int,
) -> KernelPartition:
    """Build the task grid from per-stripe densities.

    ``row_density[i]`` is α(X_{i,:}) over the FULL contraction dim (the
    concatenation of X_{ik} over k, Eq. 3); ``col_density[j]`` is α(Y_{:,j}).
    """
    nrt, nct = -(-M // tile_m), -(-N // tile_n)
    assert len(row_density) == nrt, (len(row_density), nrt)
    assert len(col_density) == nct, (len(col_density), nct)
    tasks = []
    for i in range(nrt):
        m = min(tile_m, M - i * tile_m)
        for j in range(nct):
            d = min(tile_n, N - j * tile_n)
            tasks.append(Task(
                kernel=name, i=i, j=j,
                shape=TaskShape(m=m, n=K, d=d,
                                alpha_x=float(row_density[i]),
                                alpha_y=float(col_density[j])),
            ))
    return KernelPartition(name=name, M=M, K=K, N=N,
                           tile_m=tile_m, tile_n=tile_n, tasks=tasks)


def choose_tile(M: int, N: int, target_tiles: int = 64,
                minimum: int = 128) -> tuple[int, int]:
    """Pick tile sizes giving roughly ``target_tiles`` tasks.

    Mirrors the paper's preprocessing choice: partitions must fit on-chip
    memory but be numerous enough to load-balance 8 ALU arrays + AIE.
    """
    def pick(dim):
        t = max(minimum, int(np.ceil(dim / np.sqrt(target_tiles))))
        # round up to a multiple of 128 for MXU alignment
        return -(-t // 128) * 128

    return min(pick(M), -(-M // 128) * 128), min(pick(N), -(-N // 128) * 128)
