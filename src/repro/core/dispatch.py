"""Compiled dispatch — plan-time lowering of a plan into an instruction stream.

The paper's runtime does its sparsity analysis and kernel mapping ONCE and then
streams work to the PL/AIE engines with near-zero per-kernel overhead (§III,
Alg. 4); GraphAGILE goes further and compiles the whole layer sequence into a
static instruction stream ahead of execution.  This module is that final step
for the TPU runtime: a planned kernel is lowered into a
:class:`CompiledDispatch` — the sorted fused-kernel descriptor arrays (SpDMM
entry list, SpMM triple list, batched-GEMM tile coordinates), the pooled
BlockCSR block payloads, and the padded-canvas geometry — built once with
vectorized numpy (no per-nonzero-block Python loops) and kept device-resident
in the :class:`~repro.core.plancache.PlanCache`.

Steady-state execution then goes through :func:`execute_dispatch`: ONE jitted
end-to-end program per (geometry, operand signature) that chains
pad → gemm_batch_scatter → spdmm_fused → spmm_fused → slice with the
descriptors as device arrays, so a plan-cache hit costs O(1) dict lookups on
the host instead of O(nnz blocks) of descriptor rebuilding.

Semantics vs the eager batched path (`scheduler._execute_batched`):

- GEMM and SpDMM lower exactly the same operations in the same order —
  bit-identical by construction.
- SpMM descriptors must be Y-structure-independent to be cacheable (the eager
  path packs the dense operand's col-stripes per call), so the compiled triple
  list pairs every stored A block with EVERY logical Y block of the task's
  col-stripe.  The extra pairs multiply real A blocks into exactly-zero Y
  blocks, and ``x + (±0) == x`` bitwise for every value the accumulator can
  take (it is initialized to +0 and can never become -0), so the result is
  still bit-identical.  With ``eps != 0`` an eps-thresholded pack *drops*
  small-but-nonzero Y blocks the pairing would keep, so the executor applies
  the eps mask INSIDE the traced program instead: Y blocks whose magnitudes
  are all ``<= eps`` are zeroed on device before the kernel, turning their
  pairs into the same exact bitwise no-ops — the pairing stays structure-
  independent and eps-thresholded SpMM plans compile like any other.

Activation-side kernels (dense X — the intermediate feature matrices) get the
same treatment through :class:`ActivationDispatch`: the descriptor arrays are
**capacity-parameterized** — they enumerate ``capacity`` stored-block SLOTS
per row-stripe instead of concrete stored blocks — and the slots are filled
at run time by the device-resident packer
(:func:`repro.kernels.ops.pack_activation_stripes`), whose per-slot metadata
(block-row, block-col, first-visit) rides into the fused kernels as runtime
scalar-prefetch operands.  One trace therefore serves ANY activation sparsity
within the stored-block budget; a batch that overflows the budget takes a
dense-GEMM fallback INSIDE the same program (``lax.cond``), never a retrace.
This is what recovers the paper's dynamic intermediate-data block-skip in the
compiled whole-model steady state (ROADMAP item (a); GraphAGILE's fixed-
budget overlay scheduling is the shape-stability precedent).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import halo as _halo
from repro.kernels import ops
from repro.kernels.formats import BlockCSR, block_nonzero_mask


def canvas_slots(part, block: int) -> tuple[int, int] | None:
    """Slot sizes ``(SM, SN)`` of the padded in-place canvas, or ``None``
    when the geometry cannot use the in-place index maps (interior tile
    boundaries not lcm(block, 8)-aligned — the per-task fallback)."""
    align = math.lcm(block, 8)
    tm, tn = part.tile_m, part.tile_n
    SM = tm if tm % align == 0 else -(-tm // align) * align
    SN = tn if tn % align == 0 else -(-tn // align) * align
    if (part.n_row_tiles > 1 and SM != tm) or (part.n_col_tiles > 1 and SN != tn):
        return None
    return SM, SN


@dataclasses.dataclass(frozen=True)
class DispatchGeometry:
    """Hashable static shape of a compiled dispatch — the jit cache key's
    static half (two dispatches with equal geometry share one trace)."""
    M: int
    K: int
    N: int
    tm: int
    tn: int
    SM: int
    SN: int
    B: int
    nrt: int
    nct: int
    has_gemm: bool
    has_spdmm: bool
    has_spmm: bool
    # nonzero tolerance applied to the dense operand's blocks inside the
    # traced SpMM (sub-eps Y blocks are zeroed on device — see module doc)
    eps: float = 0.0

    @property
    def m_pad(self) -> int:
        return self.nrt * self.SM

    @property
    def n_pad(self) -> int:
        return self.nct * self.SN

    @property
    def ncb(self) -> int:
        return -(-self.K // self.B)


@dataclasses.dataclass
class CompiledDispatch:
    """Device-resident instruction stream of one planned kernel.

    ``arrays`` holds the descriptor index arrays (int32) and the pooled
    stored-block payloads (float) — everything :func:`execute_dispatch`
    streams to the fused kernels.  ``fingerprint`` content-addresses the
    (structure, task assignment, geometry) this dispatch lowers, so a
    density-drift replan that lands on the same assignment transparently
    reuses it while a changed assignment misses to a fresh build.
    """
    geom: DispatchGeometry
    arrays: dict[str, jax.Array]
    fingerprint: str

    @property
    def needs_x(self) -> bool:
        """True when the dense-queue gather needs the densified X operand."""
        return self.geom.has_gemm

    @property
    def n_entries(self) -> int:
        a = self.arrays.get("sp_a_ids")
        return 0 if a is None else int(a.shape[0])

    @property
    def n_triples(self) -> int:
        a = self.arrays.get("mm_a_ids")
        return 0 if a is None else int(a.shape[0])


def plan_digest(plan, block: int) -> str:
    """Content digest of everything a dispatch is lowered from: operand
    structure key, kernel geometry, and the ORDERED task assignment (entry
    sequencing follows queue order, so order is part of the identity).

    Memoized on the plan instance — the assignment is immutable once
    planned, and hashing O(tasks) per request would reintroduce exactly the
    per-request host work the compiled path exists to remove (a replan
    builds a fresh ``KernelPlan``, so staleness is impossible)."""
    memo = getattr(plan, "_dispatch_digest", None)
    if memo is not None and memo[0] == block:
        return memo[1]
    h = hashlib.blake2b(digest_size=16)
    part = plan.part
    h.update(repr((plan.struct_key, part.M, part.K, part.N,
                   part.tile_m, part.tile_n, block)).encode())
    h.update(repr([(t.i, t.j, t.primitive) for t in plan.stq]).encode())
    h.update(repr([(t.i, t.j) for t in plan.dtq]).encode())
    placement = getattr(plan, "placement", None)
    if placement is not None:
        # Mesh geometry is part of a sharded dispatch's identity; unsharded
        # plans hash exactly as before so existing digests stay stable.
        h.update(repr(("mesh", placement.n_devices,
                       placement.band_starts)).encode())
        # Ownership geometry of the owned+halo operand layout: derived
        # deterministically from (part, placement, block), hashed so the
        # digest names the support geometry a halo dispatch is lowered for.
        h.update(repr(("own", _halo.ownership_starts(
            part.M, part.K, part.tile_m, placement.band_starts, block))
        ).encode())
    digest = h.hexdigest()
    try:
        plan._dispatch_digest = (block, digest)
    except Exception:   # frozen/slotted future variants: just recompute
        pass
    return digest


def _stripe_pool(tasks, stripes) -> tuple[dict[int, int], jax.Array]:
    """Concatenate the stored blocks of every row-stripe a task list touches
    into one device pool; returns (stripe index -> pool offset, pool)."""
    offsets: dict[int, int] = {}
    pool = []
    off = 0
    for i in sorted({t.i for t in tasks}):
        offsets[i] = off
        pool.append(stripes[i].blocks[: stripes[i].nnzb])
        off += stripes[i].nnzb
    return offsets, jnp.concatenate(pool, axis=0)


def spdmm_entry_arrays(tasks, stripes: dict[int, "BlockCSR"],
                       offsets: dict[int, int], R: int):
    """Vectorized fused-SpDMM entry list over all tasks of one kernel.

    Returns ``(a_ids, y_rows, out_rows, out_cols, first)`` sorted by output
    block with queue order as the tiebreak — element-for-element identical to
    the per-block Python loop it replaces (the stripes' own ``first`` flags
    are carried through the sort: within one output block's run the entries
    are one stripe's one block-row in stored order, whose first stored block
    is flagged 1).
    """
    out_rows, out_cols, a_ids, y_rows, firsts = [], [], [], [], []
    for task in tasks:
        s = stripes[task.i]
        nb = s.nnzb
        rid = np.asarray(s.row_ids)[:nb]
        out_rows.append(task.i * R + rid.astype(np.int64))
        out_cols.append(np.full(nb, task.j, dtype=np.int64))
        a_ids.append(offsets[task.i] + np.arange(nb, dtype=np.int64))
        y_rows.append(np.asarray(s.col_ids)[:nb].astype(np.int64))
        firsts.append(np.asarray(s.first)[:nb].astype(np.int64))
    out_rows = np.concatenate(out_rows)
    out_cols = np.concatenate(out_cols)
    a_ids = np.concatenate(a_ids)
    y_rows = np.concatenate(y_rows)
    firsts = np.concatenate(firsts)
    seq = np.arange(len(out_rows))
    order = np.lexsort((seq, out_cols, out_rows))
    return (a_ids[order].astype(np.int32), y_rows[order].astype(np.int32),
            out_rows[order].astype(np.int32), out_cols[order].astype(np.int32),
            firsts[order].astype(np.int32))


def _spmm_dense_y_triples(tasks, part, stripes, offsets, R: int, C: int,
                          n_y_block_cols: int):
    """Vectorized fused-SpMM triple list with a Y-structure-INDEPENDENT
    pairing: every stored A block of a task's row-stripe is paired with every
    logical Y block of the task's col-stripe (``y_id = ib * Ctot + cb`` into
    the row-major block pool :func:`repro.kernels.ops.blockize` builds from
    the dense operand at run time).  Zero Y blocks contribute exact bitwise
    no-ops, so the result matches the structure-intersecting eager pairing —
    see the module docstring for the eps caveat.
    """
    out_rows, out_cols, a_ids, y_ids = [], [], [], []
    for task in tasks:
        s = stripes[task.i]
        nb = s.nnzb
        nbj = -(-part.col_extent(task.j) // stripes[task.i].block_size)
        rid = np.asarray(s.row_ids)[:nb].astype(np.int64)
        cid = np.asarray(s.col_ids)[:nb].astype(np.int64)
        kb = np.tile(np.arange(nbj, dtype=np.int64), nb)
        out_rows.append(np.repeat(task.i * R + rid, nbj))
        out_cols.append(task.j * C + kb)
        a_ids.append(np.repeat(offsets[task.i] + np.arange(nb, dtype=np.int64),
                               nbj))
        y_ids.append(np.repeat(cid, nbj) * n_y_block_cols + task.j * C + kb)
    out_rows = np.concatenate(out_rows)
    out_cols = np.concatenate(out_cols)
    a_ids = np.concatenate(a_ids)
    y_ids = np.concatenate(y_ids)
    order = np.lexsort((y_ids, a_ids, out_cols, out_rows))
    out_rows, out_cols = out_rows[order], out_cols[order]
    first = np.ones(len(out_rows), dtype=np.int32)
    if len(first) > 1:
        same = ((out_rows[1:] == out_rows[:-1])
                & (out_cols[1:] == out_cols[:-1]))
        first[1:][same] = 0
    return (a_ids[order].astype(np.int32), y_ids[order].astype(np.int32),
            out_rows.astype(np.int32), out_cols.astype(np.int32), first)


def build_dispatch(part, stq, dtq, stripes: dict[int, "BlockCSR"],
                   *, block: int, eps: float = 0.0,
                   fingerprint: str = "",
                   faults: object = None) -> CompiledDispatch | None:
    """Lower a planned kernel into a :class:`CompiledDispatch`.

    O(nnz blocks) of VECTORIZED numpy + one device upload, paid once per
    (structure, assignment, geometry); returns ``None`` when the canvas
    geometry cannot take the in-place index maps (caller falls back to the
    per-task path, exactly like the eager batched dispatch).  ``faults`` is
    the optional fault injector probed at the ``lower`` site — descriptor
    lowering is an instrumented degradation path.
    """
    if faults is not None:
        faults.probe("lower", detail=f"dispatch:{part.name}")
    slots = canvas_slots(part, block)
    if slots is None:
        return None
    SM, SN = slots
    B = block
    R, C = SM // B, SN // B
    geom = DispatchGeometry(
        M=part.M, K=part.K, N=part.N, tm=part.tile_m, tn=part.tile_n,
        SM=SM, SN=SN, B=B, nrt=part.n_row_tiles, nct=part.n_col_tiles,
        has_gemm=bool(dtq),
        has_spdmm=any(t.primitive != "SpMM" for t in stq),
        has_spmm=any(t.primitive == "SpMM" for t in stq),
        eps=eps)
    arrays: dict[str, jax.Array] = {}

    if dtq:
        arrays["gemm_rows"] = jnp.asarray(
            np.array([t.i for t in dtq], dtype=np.int32))
        arrays["gemm_cols"] = jnp.asarray(
            np.array([t.j for t in dtq], dtype=np.int32))

    spdmm_tasks = [t for t in stq if t.primitive != "SpMM"]
    spmm_tasks = [t for t in stq if t.primitive == "SpMM"]

    if spdmm_tasks:
        offsets, pool = _stripe_pool(spdmm_tasks, stripes)
        a_ids, y_rows, out_rows, out_cols, first = spdmm_entry_arrays(
            spdmm_tasks, stripes, offsets, R)
        arrays["sp_pool"] = pool
        arrays["sp_a_ids"] = jnp.asarray(a_ids)
        arrays["sp_y_rows"] = jnp.asarray(y_rows)
        arrays["sp_out_rows"] = jnp.asarray(out_rows)
        arrays["sp_out_cols"] = jnp.asarray(out_cols)
        arrays["sp_first"] = jnp.asarray(first)

    if spmm_tasks:
        offsets, pool = _stripe_pool(spmm_tasks, stripes)
        a_ids, y_ids, out_rows, out_cols, first = _spmm_dense_y_triples(
            spmm_tasks, part, stripes, offsets, R, C,
            n_y_block_cols=geom.nct * C)
        arrays["mm_pool"] = pool
        arrays["mm_a_ids"] = jnp.asarray(a_ids)
        arrays["mm_y_ids"] = jnp.asarray(y_ids)
        arrays["mm_out_rows"] = jnp.asarray(out_rows)
        arrays["mm_out_cols"] = jnp.asarray(out_cols)
        arrays["mm_first"] = jnp.asarray(first)

    return CompiledDispatch(geom=geom, arrays=arrays, fingerprint=fingerprint)


# --------------------------------------------------------------- execution
def _stripe_padded_y(geom, y):
    """Dense operand laid out with each col-stripe padded to ``SN`` columns
    and K padded to block multiples — the fused kernels' Y layout.  Works
    for both geometry kinds (duck-typed on the shared fields)."""
    B = geom.B
    ncb = geom.ncb
    y_pad = jnp.pad(y, ((0, ncb * B - geom.K),
                        (0, geom.nct * geom.tn - geom.N)))
    return jnp.pad(y_pad.reshape(ncb * B, geom.nct, geom.tn),
                   ((0, 0), (0, 0), (0, geom.SN - geom.tn))
                   ).reshape(ncb * B, geom.nct * geom.SN)


def _masked_y_blocks(geom, y_f):
    """Blockized dense operand with the eps mask applied on device: blocks
    whose magnitudes are all ``<= eps`` are zeroed, so the structure-
    independent pairing contributes exact bitwise no-ops for exactly the
    blocks an eps-thresholded eager pack would have dropped."""
    y_blocks = ops.blockize(y_f, geom.B)
    if geom.eps != 0.0:
        keep = block_nonzero_mask(y_blocks, geom.eps, axis=(-2, -1), xp=jnp)
        y_blocks = jnp.where(keep[:, None, None], y_blocks,
                             jnp.zeros((), y_blocks.dtype))
    return y_blocks


def _gemm_y_panel(geom, y):
    """Col-stripe-padded GEMM operand panel ``(K, nct, SN)`` from the raw
    dense operand — the layout ``gemm_batch_scatter`` gathers col stripes
    from."""
    y_p = jnp.pad(y, ((0, 0), (0, geom.nct * geom.tn - geom.N))
                  ).reshape(geom.K, geom.nct, geom.tn)
    if geom.SN != geom.tn:
        y_p = jnp.pad(y_p, ((0, 0), (0, 0), (0, geom.SN - geom.tn)))
    return y_p


def _gemm_scatter_panel(geom, arrays, x, y_p, z, *, interpret: bool):
    """Dense-queue section on a PRE-BUILT operand panel: gather the tasks'
    row/col stripes and scatter one batched GEMM into the canvas."""
    rows, cols = arrays["gemm_rows"], arrays["gemm_cols"]
    x_p = jnp.pad(x, ((0, geom.m_pad - geom.M), (0, 0)))
    xs = x_p.reshape(geom.nrt, geom.SM, geom.K)[rows]
    ys = jnp.moveaxis(y_p, 1, 0)[cols]
    return ops.gemm_batch_scatter(xs, ys, rows, cols, z, interpret=interpret)


def _gemm_scatter(geom, arrays, x, y, z, *, interpret: bool):
    """Dense-queue section shared by both dispatch kinds (raw-operand
    entry point, kept for the activation path)."""
    return _gemm_scatter_panel(geom, arrays, x, _gemm_y_panel(geom, y), z,
                               interpret=interpret)


def apply_dispatch(geom: DispatchGeometry, arrays, x, y, *, interpret: bool):
    """Traceable end-to-end executor body: pad → batched GEMM scatter →
    fused SpDMM → fused SpMM → slice, on ONE aliased canvas.  ``x`` (the
    densified operand) may be ``None`` when the plan has no dense-queue
    tasks.  Inlines into larger jitted programs (`models.gnn.compile_model`).
    """
    if geom.has_gemm and x is None:
        raise ValueError("compiled dispatch: dense-queue tasks need the "
                         "densified x operand (got x=None)")
    y_f = (_stripe_padded_y(geom, y)
           if (geom.has_spdmm or geom.has_spmm) else None)
    y_p = _gemm_y_panel(geom, y) if geom.has_gemm else None
    return apply_prepared(geom, arrays, x, y_f, y_p, interpret=interpret)


def apply_prepared(geom: DispatchGeometry, arrays, x, y_f, y_p,
                   *, interpret: bool):
    """Executor body on PRE-LAID-OUT dense operands: ``y_f`` is the
    stripe-padded operand matrix (ANY block-row count — the halo-sharded
    path passes each shard's LOCAL owned+halo buffer, whose slots the
    descriptors were lowered against), ``y_p`` the GEMM panel (required
    when ``geom.has_gemm``).  The fused kernels index Y only through the
    descriptor block-row ids, so the operand's leading extent is free."""
    B, SM, SN = geom.B, geom.SM, geom.SN
    M_pad, N_pad = geom.m_pad, geom.n_pad
    z = jnp.zeros((M_pad, N_pad), dtype=jnp.float32)

    if geom.has_gemm:
        z = _gemm_scatter_panel(geom, arrays, x, y_p, z, interpret=interpret)

    if geom.has_spdmm:
        z = ops.spdmm_fused(
            arrays["sp_pool"], y_f, arrays["sp_a_ids"], arrays["sp_y_rows"],
            arrays["sp_out_rows"], arrays["sp_out_cols"], arrays["sp_first"],
            block_size=B, bn=SN, m_pad=M_pad, interpret=interpret, z=z)

    if geom.has_spmm:
        y_blocks = _masked_y_blocks(geom, y_f)
        z = ops.spmm_fused(
            arrays["mm_pool"], y_blocks, arrays["mm_a_ids"],
            arrays["mm_y_ids"], arrays["mm_out_rows"], arrays["mm_out_cols"],
            arrays["mm_first"], block_size=B, m_pad=M_pad, n_pad=N_pad,
            interpret=interpret, z=z)

    return z[:geom.M, :geom.N]


@functools.partial(jax.jit, static_argnames=("geom", "interpret"))
def _run_dispatch(geom, arrays, x, y, *, interpret):
    return apply_dispatch(geom, arrays, x, y, interpret=interpret)


# Trace-cache observability: jax.jit caches per (geometry, operand signature);
# this mirror of that key set lets engines report honest trace hit counts.
_TRACE_SEEN: set = set()
_TRACE_LOCK = threading.Lock()


def _signature(geom, arrays, x, y, interpret):
    arr_sig = tuple(sorted((k, v.shape, str(v.dtype))
                           for k, v in arrays.items()))
    x_sig = None if x is None else (tuple(x.shape), str(x.dtype))
    return (geom, arr_sig, x_sig, tuple(y.shape), str(y.dtype), interpret)


def reset_trace_registry() -> None:
    """Forget which executor signatures were seen (tests/benchmarks).  Note
    jax's own jit cache is NOT cleared — after a reset the first call per
    signature is counted as a build again even though jax may reuse its
    trace; pair with ``jax.clear_caches()`` when that distinction matters."""
    with _TRACE_LOCK:
        _TRACE_SEEN.clear()


def execute_dispatch(d: CompiledDispatch, x, y, *, interpret: bool,
                     stats=None) -> jax.Array:
    """Run one compiled kernel: a single jitted call, zero host descriptor
    work.  ``stats`` (a ``CacheStats``) receives trace-cache accounting."""
    y = jnp.asarray(y)
    key = _signature(d.geom, d.arrays, x, y, interpret)
    with _TRACE_LOCK:
        hit = key in _TRACE_SEEN
        _TRACE_SEEN.add(key)
    if stats is not None:
        if hit:
            stats.trace_cache_hits += 1
        else:
            stats.trace_builds += 1
    return _run_dispatch(d.geom, d.arrays, x, y, interpret=interpret)


# ------------------------------------ activation-side capacity block-skip
@dataclasses.dataclass(frozen=True)
class ActivationGeometry(DispatchGeometry):
    """Hashable static shape of a compiled ACTIVATION dispatch.

    Extends :class:`DispatchGeometry` with the stored-block budget per
    row-stripe — because the descriptor arrays enumerate capacity slots,
    not concrete stored blocks: the trace key must distinguish two budgets,
    but NOT two sparsity patterns (that independence is the whole point).
    The budget is either uniform (``cap``, historical layout) or a
    per-stripe vector (``caps`` — skew-aware: each stripe only as many
    slots as its warmup need × slack; stripes live at flat offsets
    ``cumsum(caps)``).  Dataclass equality is class-aware, so an activation
    geometry never collides with an adjacency one in the jit/trace
    registries.
    """
    cap: int = 0
    # per-stripe budgets; empty tuple = uniform ``cap`` for every stripe
    caps: tuple = ()

    @property
    def R(self) -> int:
        return self.SM // self.B

    @property
    def C(self) -> int:
        return self.SN // self.B

    @property
    def cap_vec(self) -> np.ndarray:
        """Per-stripe budget vector (length ``nrt``), whichever form the
        geometry stores."""
        if self.caps:
            return np.asarray(self.caps, dtype=np.int64)
        return np.full(self.nrt, self.cap, dtype=np.int64)

    @property
    def slot_offsets(self) -> np.ndarray:
        """Flat slot offset of each stripe (length ``nrt + 1``)."""
        return np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(self.cap_vec)])

    @property
    def total_slots(self) -> int:
        return int(self.cap_vec.sum())


@dataclasses.dataclass
class ActivationDispatch:
    """Capacity-parameterized instruction stream of one activation-side
    (dense X) kernel.  ``arrays`` holds ONLY static int32 descriptor arrays
    — slot ids, output col-stripes, base rows — valid for every input; the
    data-dependent half (block payloads, per-slot block-row/col/first) is
    produced at run time by the device packer and joined to these
    descriptors inside the traced program."""
    geom: ActivationGeometry
    arrays: dict[str, jax.Array]
    fingerprint: str

    @property
    def n_entries(self) -> int:
        a = self.arrays.get("asp_a_ids")
        return 0 if a is None else int(a.shape[0])

    @property
    def n_triples(self) -> int:
        a = self.arrays.get("amm_a_ids")
        return 0 if a is None else int(a.shape[0])


def activation_capacity(x, part, block: int, *, eps: float = 0.0,
                        slack: float = 1.5) -> int | None:
    """Stored-block budget per row-stripe from a warmup activation.

    Counts, per canvas row-stripe, the slots the device packer will need
    (stored blocks plus one filler per empty block-row, canvas padding rows
    included) and budgets ``max * slack`` so later batches whose sparsity
    wiggles within the drift threshold still fit without a retrace.
    ``None`` when the canvas geometry cannot take the in-place index maps.
    """
    needs = _stripe_needs(x, part, block, eps=eps)
    if needs is None:
        return None
    R, C = _canvas_rc(part, block)
    return min(R * C, max(1, math.ceil(int(needs.max()) * slack)))


def _canvas_rc(part, block: int) -> tuple[int, int]:
    SM, _ = canvas_slots(part, block)
    return SM // block, -(-part.K // block)


def _stripe_needs(x, part, block: int, *, eps: float = 0.0):
    """Per-stripe slot needs of a warmup activation (stored blocks plus one
    filler per empty block-row, canvas padding rows included) — the shared
    counting core of the uniform and per-stripe budget sizers."""
    slots = canvas_slots(part, block)
    if slots is None:
        return None
    SM, _ = slots
    B = block
    S, R, C = part.n_row_tiles, SM // B, -(-part.K // B)
    x = np.asarray(x)
    xp = np.zeros((S * R * B, C * B), dtype=x.dtype)
    xp[: x.shape[0], : x.shape[1]] = x
    xb = xp.reshape(S, R, B, C, B)
    mask = block_nonzero_mask(xb, eps, axis=(2, 4))
    return np.maximum(mask.sum(axis=2), 1).sum(axis=1)     # (S,)


def activation_budgets(x, part, block: int, *, eps: float = 0.0,
                       slack: float = 1.5):
    """Per-stripe stored-block budget VECTOR from a warmup activation.

    The skew-aware refinement of :func:`activation_capacity`: each stripe
    is budgeted ``its own need × slack`` (clamped to ``[1, R*C]``) instead
    of every stripe paying for the densest one.  On skewed activations this
    cuts padded-slot waste proportionally to the skew, and since drift only
    wiggles a fixed support, warmup needs bound later needs per stripe just
    as they do globally.  Returns an int64 array of length
    ``part.n_row_tiles``, or ``None`` when the canvas geometry cannot take
    the in-place index maps.
    """
    needs = _stripe_needs(x, part, block, eps=eps)
    if needs is None:
        return None
    R, C = _canvas_rc(part, block)
    return np.clip(np.ceil(needs * slack).astype(np.int64), 1, R * C)


def build_activation_dispatch(part, stq, dtq, *, block: int, capacity,
                              eps: float = 0.0, fingerprint: str = "",
                              faults: object = None
                              ) -> ActivationDispatch | None:
    """Lower an activation-side plan into capacity-slot descriptor arrays.

    ``capacity`` is a uniform int budget or a per-stripe vector (see
    :func:`activation_budgets`); descriptors address slots at the stripe's
    flat offset, so the uniform case keeps its historical
    ``stripe * cap + slot`` layout exactly.  Entry order is (task, slot)
    for SpDMM and (task, y-block-col, slot) for SpMM: within one ordering
    unit the runtime slot metadata is row-major, so every output block is
    still visited in ONE consecutive run (the TPU output-residency
    obligation) for ANY stored pattern — and within a run the real
    contributions arrive in the same (block-row, block-col) order the eager
    host pack emits, so sums are bit-identical.  Returns ``None`` for
    canvas geometries the in-place index maps cannot take.
    """
    if faults is not None:
        faults.probe("pack", detail=f"act:{part.name}")
    slots = canvas_slots(part, block)
    if slots is None:
        return None
    SM, SN = slots
    B = block
    R, C = SM // B, SN // B
    cap_arr = np.asarray(capacity, dtype=np.int64)
    uniform = cap_arr.ndim == 0
    if uniform:
        cap_arr = np.full(part.n_row_tiles, int(cap_arr), dtype=np.int64)
    assert cap_arr.shape == (part.n_row_tiles,), (cap_arr.shape, part)
    offs = np.concatenate([np.zeros(1, np.int64), np.cumsum(cap_arr)])
    geom = ActivationGeometry(
        M=part.M, K=part.K, N=part.N, tm=part.tile_m, tn=part.tile_n,
        SM=SM, SN=SN, B=B, nrt=part.n_row_tiles, nct=part.n_col_tiles,
        cap=int(cap_arr[0]) if uniform else 0,
        caps=() if uniform else tuple(int(c) for c in cap_arr),
        eps=eps,
        has_gemm=bool(dtq),
        has_spdmm=any(t.primitive != "SpMM" for t in stq),
        has_spmm=any(t.primitive == "SpMM" for t in stq))
    arrays: dict[str, jax.Array] = {}

    if dtq:
        arrays["gemm_rows"] = jnp.asarray(
            np.array([t.i for t in dtq], dtype=np.int32))
        arrays["gemm_cols"] = jnp.asarray(
            np.array([t.j for t in dtq], dtype=np.int32))

    spdmm_tasks = sorted((t for t in stq if t.primitive != "SpMM"),
                         key=lambda t: (t.i, t.j))
    spmm_tasks = sorted((t for t in stq if t.primitive == "SpMM"),
                        key=lambda t: (t.i, t.j))

    if spdmm_tasks:
        arrays["asp_a_ids"] = jnp.asarray(np.concatenate(
            [offs[t.i] + np.arange(cap_arr[t.i], dtype=np.int64)
             for t in spdmm_tasks]).astype(np.int32))
        arrays["asp_out_cols"] = jnp.asarray(np.concatenate(
            [np.full(cap_arr[t.i], t.j, dtype=np.int64)
             for t in spdmm_tasks]).astype(np.int32))
        arrays["asp_base_rows"] = jnp.asarray(np.concatenate(
            [np.full(cap_arr[t.i], t.i * R, dtype=np.int64)
             for t in spdmm_tasks]).astype(np.int32))

    if spmm_tasks:
        a_ids, y_cols, base_rows = [], [], []
        for t in spmm_tasks:
            nbj = -(-part.col_extent(t.j) // B)
            cap_i = int(cap_arr[t.i])
            a_ids.append(np.tile(
                offs[t.i] + np.arange(cap_i, dtype=np.int64), nbj))
            y_cols.append(np.repeat(t.j * C + np.arange(nbj, dtype=np.int64),
                                    cap_i))
            base_rows.append(np.full(nbj * cap_i, t.i * R, dtype=np.int64))
        arrays["amm_a_ids"] = jnp.asarray(
            np.concatenate(a_ids).astype(np.int32))
        # y block-col == output block-col for every triple of a task
        arrays["amm_y_cols"] = jnp.asarray(
            np.concatenate(y_cols).astype(np.int32))
        arrays["amm_base_rows"] = jnp.asarray(
            np.concatenate(base_rows).astype(np.int32))

    return ActivationDispatch(geom=geom, arrays=arrays,
                              fingerprint=fingerprint)


def apply_activation_dispatch(geom: ActivationGeometry, arrays, x, y, *,
                              interpret: bool):
    """Traceable activation-side executor: device-pack X into capacity
    slots, join the slot metadata to the static descriptors, and drain the
    plan's queues on one canvas — or, when the batch overflows the budget,
    fall back to ONE dense GEMM inside the same program (``lax.cond``:
    same trace, no recompilation, the result is the plain dense route's).

    Returns ``(z, diag)`` where ``diag`` carries the block-skip telemetry
    the serving layer and the benchmark gate consume: ``stored`` (total
    slots filled with real blocks), ``capacity``/``logical`` (budget and
    full block count), and the ``overflow`` flag."""
    B, SM, SN = geom.B, geom.SM, geom.SN
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    (pool, row_m, col_m, first_m, nnzb, real,
     overflow) = ops.pack_activation_stripes(
        x, block=B, n_stripes=geom.nrt, slot_rows=geom.R,
        n_block_cols=geom.ncb,
        capacity=np.asarray(geom.caps) if geom.caps else geom.cap,
        eps=geom.eps)

    def _dense():
        return ops.gemm(x, y, interpret=interpret, out_dtype=jnp.float32)

    def _skip():
        z = jnp.zeros((geom.m_pad, geom.n_pad), dtype=jnp.float32)
        if geom.has_gemm:
            z = _gemm_scatter(geom, arrays, x, y, z, interpret=interpret)
        if geom.has_spdmm or geom.has_spmm:
            y_f = _stripe_padded_y(geom, y)
        if geom.has_spdmm:
            a_ids = arrays["asp_a_ids"]
            z = ops.spdmm_fused(
                pool, y_f, a_ids, col_m[a_ids],
                arrays["asp_base_rows"] + row_m[a_ids],
                arrays["asp_out_cols"], first_m[a_ids],
                block_size=B, bn=SN, m_pad=geom.m_pad, interpret=interpret,
                z=z)
        if geom.has_spmm:
            y_blocks = _masked_y_blocks(geom, y_f)
            a_ids = arrays["amm_a_ids"]
            y_ids = col_m[a_ids] * (geom.nct * geom.C) + arrays["amm_y_cols"]
            z = ops.spmm_fused(
                pool, y_blocks, a_ids, y_ids,
                arrays["amm_base_rows"] + row_m[a_ids],
                arrays["amm_y_cols"], first_m[a_ids],
                block_size=B, m_pad=geom.m_pad, n_pad=geom.n_pad,
                interpret=interpret, z=z)
        return z[:geom.M, :geom.N]

    z = jax.lax.cond(overflow, _dense, _skip)
    # ``stored`` counts REAL blocks (empty-row fillers excluded) and
    # ``logical`` the block positions of the LOGICAL extent (canvas padding
    # rows excluded), so 1 - stored/logical is the honest skip ratio: 0 for
    # a dense activation, ~1 for an all-zero one.
    diag = {
        "stored": jnp.sum(real),
        "capacity": jnp.int32(geom.total_slots),
        "logical": jnp.int32(-(-geom.M // geom.B) * geom.ncb),
        "overflow": overflow,
    }
    return z, diag


@functools.partial(jax.jit, static_argnames=("geom", "interpret"))
def _run_activation(geom, arrays, x, y, *, interpret):
    return apply_activation_dispatch(geom, arrays, x, y, interpret=interpret)


def execute_activation(d: ActivationDispatch, x, y, *, interpret: bool,
                       stats=None):
    """Run one activation-side kernel through the capacity block-skip route:
    a single jitted call whose trace is reused for EVERY input sparsity
    within budget.  Returns ``(z, diag)``; ``stats`` receives the same
    trace-cache accounting as :func:`execute_dispatch`."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    key = _signature(d.geom, d.arrays, x, y, interpret)
    with _TRACE_LOCK:
        hit = key in _TRACE_SEEN
        _TRACE_SEEN.add(key)
    if stats is not None:
        if hit:
            stats.trace_cache_hits += 1
        else:
            stats.trace_builds += 1
    return _run_activation(d.geom, d.arrays, x, y, interpret=interpret)
