"""Compiled dispatch — plan-time lowering of a plan into an instruction stream.

The paper's runtime does its sparsity analysis and kernel mapping ONCE and then
streams work to the PL/AIE engines with near-zero per-kernel overhead (§III,
Alg. 4); GraphAGILE goes further and compiles the whole layer sequence into a
static instruction stream ahead of execution.  This module is that final step
for the TPU runtime: a planned kernel is lowered into a
:class:`CompiledDispatch` — the sorted fused-kernel descriptor arrays (SpDMM
entry list, SpMM triple list, batched-GEMM tile coordinates), the pooled
BlockCSR block payloads, and the padded-canvas geometry — built once with
vectorized numpy (no per-nonzero-block Python loops) and kept device-resident
in the :class:`~repro.core.plancache.PlanCache`.

Steady-state execution then goes through :func:`execute_dispatch`: ONE jitted
end-to-end program per (geometry, operand signature) that chains
pad → gemm_batch_scatter → spdmm_fused → spmm_fused → slice with the
descriptors as device arrays, so a plan-cache hit costs O(1) dict lookups on
the host instead of O(nnz blocks) of descriptor rebuilding.

Semantics vs the eager batched path (`scheduler._execute_batched`):

- GEMM and SpDMM lower exactly the same operations in the same order —
  bit-identical by construction.
- SpMM descriptors must be Y-structure-independent to be cacheable (the eager
  path packs the dense operand's col-stripes per call), so the compiled triple
  list pairs every stored A block with EVERY logical Y block of the task's
  col-stripe.  The extra pairs multiply real A blocks into exactly-zero Y
  blocks, and ``x + (±0) == x`` bitwise for every value the accumulator can
  take (it is initialized to +0 and can never become -0), so the result is
  still bit-identical — but only when ``eps == 0``: an eps-thresholded pack
  *drops* small-but-nonzero Y blocks the compiled path would keep, so the
  engine declines to compile SpMM-bearing plans with ``eps != 0``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.formats import BlockCSR


def canvas_slots(part, block: int) -> tuple[int, int] | None:
    """Slot sizes ``(SM, SN)`` of the padded in-place canvas, or ``None``
    when the geometry cannot use the in-place index maps (interior tile
    boundaries not lcm(block, 8)-aligned — the per-task fallback)."""
    align = math.lcm(block, 8)
    tm, tn = part.tile_m, part.tile_n
    SM = tm if tm % align == 0 else -(-tm // align) * align
    SN = tn if tn % align == 0 else -(-tn // align) * align
    if (part.n_row_tiles > 1 and SM != tm) or (part.n_col_tiles > 1 and SN != tn):
        return None
    return SM, SN


@dataclasses.dataclass(frozen=True)
class DispatchGeometry:
    """Hashable static shape of a compiled dispatch — the jit cache key's
    static half (two dispatches with equal geometry share one trace)."""
    M: int
    K: int
    N: int
    tm: int
    tn: int
    SM: int
    SN: int
    B: int
    nrt: int
    nct: int
    has_gemm: bool
    has_spdmm: bool
    has_spmm: bool

    @property
    def m_pad(self) -> int:
        return self.nrt * self.SM

    @property
    def n_pad(self) -> int:
        return self.nct * self.SN

    @property
    def ncb(self) -> int:
        return -(-self.K // self.B)


@dataclasses.dataclass
class CompiledDispatch:
    """Device-resident instruction stream of one planned kernel.

    ``arrays`` holds the descriptor index arrays (int32) and the pooled
    stored-block payloads (float) — everything :func:`execute_dispatch`
    streams to the fused kernels.  ``fingerprint`` content-addresses the
    (structure, task assignment, geometry) this dispatch lowers, so a
    density-drift replan that lands on the same assignment transparently
    reuses it while a changed assignment misses to a fresh build.
    """
    geom: DispatchGeometry
    arrays: dict[str, jax.Array]
    fingerprint: str

    @property
    def needs_x(self) -> bool:
        """True when the dense-queue gather needs the densified X operand."""
        return self.geom.has_gemm

    @property
    def n_entries(self) -> int:
        a = self.arrays.get("sp_a_ids")
        return 0 if a is None else int(a.shape[0])

    @property
    def n_triples(self) -> int:
        a = self.arrays.get("mm_a_ids")
        return 0 if a is None else int(a.shape[0])


def plan_digest(plan, block: int) -> str:
    """Content digest of everything a dispatch is lowered from: operand
    structure key, kernel geometry, and the ORDERED task assignment (entry
    sequencing follows queue order, so order is part of the identity).

    Memoized on the plan instance — the assignment is immutable once
    planned, and hashing O(tasks) per request would reintroduce exactly the
    per-request host work the compiled path exists to remove (a replan
    builds a fresh ``KernelPlan``, so staleness is impossible)."""
    memo = getattr(plan, "_dispatch_digest", None)
    if memo is not None and memo[0] == block:
        return memo[1]
    h = hashlib.blake2b(digest_size=16)
    part = plan.part
    h.update(repr((plan.struct_key, part.M, part.K, part.N,
                   part.tile_m, part.tile_n, block)).encode())
    h.update(repr([(t.i, t.j, t.primitive) for t in plan.stq]).encode())
    h.update(repr([(t.i, t.j) for t in plan.dtq]).encode())
    digest = h.hexdigest()
    try:
        plan._dispatch_digest = (block, digest)
    except Exception:   # frozen/slotted future variants: just recompute
        pass
    return digest


def _stripe_pool(tasks, stripes) -> tuple[dict[int, int], jax.Array]:
    """Concatenate the stored blocks of every row-stripe a task list touches
    into one device pool; returns (stripe index -> pool offset, pool)."""
    offsets: dict[int, int] = {}
    pool = []
    off = 0
    for i in sorted({t.i for t in tasks}):
        offsets[i] = off
        pool.append(stripes[i].blocks[: stripes[i].nnzb])
        off += stripes[i].nnzb
    return offsets, jnp.concatenate(pool, axis=0)


def spdmm_entry_arrays(tasks, stripes: dict[int, "BlockCSR"],
                       offsets: dict[int, int], R: int):
    """Vectorized fused-SpDMM entry list over all tasks of one kernel.

    Returns ``(a_ids, y_rows, out_rows, out_cols, first)`` sorted by output
    block with queue order as the tiebreak — element-for-element identical to
    the per-block Python loop it replaces (the stripes' own ``first`` flags
    are carried through the sort: within one output block's run the entries
    are one stripe's one block-row in stored order, whose first stored block
    is flagged 1).
    """
    out_rows, out_cols, a_ids, y_rows, firsts = [], [], [], [], []
    for task in tasks:
        s = stripes[task.i]
        nb = s.nnzb
        rid = np.asarray(s.row_ids)[:nb]
        out_rows.append(task.i * R + rid.astype(np.int64))
        out_cols.append(np.full(nb, task.j, dtype=np.int64))
        a_ids.append(offsets[task.i] + np.arange(nb, dtype=np.int64))
        y_rows.append(np.asarray(s.col_ids)[:nb].astype(np.int64))
        firsts.append(np.asarray(s.first)[:nb].astype(np.int64))
    out_rows = np.concatenate(out_rows)
    out_cols = np.concatenate(out_cols)
    a_ids = np.concatenate(a_ids)
    y_rows = np.concatenate(y_rows)
    firsts = np.concatenate(firsts)
    seq = np.arange(len(out_rows))
    order = np.lexsort((seq, out_cols, out_rows))
    return (a_ids[order].astype(np.int32), y_rows[order].astype(np.int32),
            out_rows[order].astype(np.int32), out_cols[order].astype(np.int32),
            firsts[order].astype(np.int32))


def _spmm_dense_y_triples(tasks, part, stripes, offsets, R: int, C: int,
                          n_y_block_cols: int):
    """Vectorized fused-SpMM triple list with a Y-structure-INDEPENDENT
    pairing: every stored A block of a task's row-stripe is paired with every
    logical Y block of the task's col-stripe (``y_id = ib * Ctot + cb`` into
    the row-major block pool :func:`repro.kernels.ops.blockize` builds from
    the dense operand at run time).  Zero Y blocks contribute exact bitwise
    no-ops, so the result matches the structure-intersecting eager pairing —
    see the module docstring for the eps caveat.
    """
    out_rows, out_cols, a_ids, y_ids = [], [], [], []
    for task in tasks:
        s = stripes[task.i]
        nb = s.nnzb
        nbj = -(-part.col_extent(task.j) // stripes[task.i].block_size)
        rid = np.asarray(s.row_ids)[:nb].astype(np.int64)
        cid = np.asarray(s.col_ids)[:nb].astype(np.int64)
        kb = np.tile(np.arange(nbj, dtype=np.int64), nb)
        out_rows.append(np.repeat(task.i * R + rid, nbj))
        out_cols.append(task.j * C + kb)
        a_ids.append(np.repeat(offsets[task.i] + np.arange(nb, dtype=np.int64),
                               nbj))
        y_ids.append(np.repeat(cid, nbj) * n_y_block_cols + task.j * C + kb)
    out_rows = np.concatenate(out_rows)
    out_cols = np.concatenate(out_cols)
    a_ids = np.concatenate(a_ids)
    y_ids = np.concatenate(y_ids)
    order = np.lexsort((y_ids, a_ids, out_cols, out_rows))
    out_rows, out_cols = out_rows[order], out_cols[order]
    first = np.ones(len(out_rows), dtype=np.int32)
    if len(first) > 1:
        same = ((out_rows[1:] == out_rows[:-1])
                & (out_cols[1:] == out_cols[:-1]))
        first[1:][same] = 0
    return (a_ids[order].astype(np.int32), y_ids[order].astype(np.int32),
            out_rows.astype(np.int32), out_cols.astype(np.int32), first)


def build_dispatch(part, stq, dtq, stripes: dict[int, "BlockCSR"],
                   *, block: int, fingerprint: str = "") -> CompiledDispatch | None:
    """Lower a planned kernel into a :class:`CompiledDispatch`.

    O(nnz blocks) of VECTORIZED numpy + one device upload, paid once per
    (structure, assignment, geometry); returns ``None`` when the canvas
    geometry cannot take the in-place index maps (caller falls back to the
    per-task path, exactly like the eager batched dispatch).
    """
    slots = canvas_slots(part, block)
    if slots is None:
        return None
    SM, SN = slots
    B = block
    R, C = SM // B, SN // B
    geom = DispatchGeometry(
        M=part.M, K=part.K, N=part.N, tm=part.tile_m, tn=part.tile_n,
        SM=SM, SN=SN, B=B, nrt=part.n_row_tiles, nct=part.n_col_tiles,
        has_gemm=bool(dtq),
        has_spdmm=any(t.primitive != "SpMM" for t in stq),
        has_spmm=any(t.primitive == "SpMM" for t in stq))
    arrays: dict[str, jax.Array] = {}

    if dtq:
        arrays["gemm_rows"] = jnp.asarray(
            np.array([t.i for t in dtq], dtype=np.int32))
        arrays["gemm_cols"] = jnp.asarray(
            np.array([t.j for t in dtq], dtype=np.int32))

    spdmm_tasks = [t for t in stq if t.primitive != "SpMM"]
    spmm_tasks = [t for t in stq if t.primitive == "SpMM"]

    if spdmm_tasks:
        offsets, pool = _stripe_pool(spdmm_tasks, stripes)
        a_ids, y_rows, out_rows, out_cols, first = spdmm_entry_arrays(
            spdmm_tasks, stripes, offsets, R)
        arrays["sp_pool"] = pool
        arrays["sp_a_ids"] = jnp.asarray(a_ids)
        arrays["sp_y_rows"] = jnp.asarray(y_rows)
        arrays["sp_out_rows"] = jnp.asarray(out_rows)
        arrays["sp_out_cols"] = jnp.asarray(out_cols)
        arrays["sp_first"] = jnp.asarray(first)

    if spmm_tasks:
        offsets, pool = _stripe_pool(spmm_tasks, stripes)
        a_ids, y_ids, out_rows, out_cols, first = _spmm_dense_y_triples(
            spmm_tasks, part, stripes, offsets, R, C,
            n_y_block_cols=geom.nct * C)
        arrays["mm_pool"] = pool
        arrays["mm_a_ids"] = jnp.asarray(a_ids)
        arrays["mm_y_ids"] = jnp.asarray(y_ids)
        arrays["mm_out_rows"] = jnp.asarray(out_rows)
        arrays["mm_out_cols"] = jnp.asarray(out_cols)
        arrays["mm_first"] = jnp.asarray(first)

    return CompiledDispatch(geom=geom, arrays=arrays, fingerprint=fingerprint)


# --------------------------------------------------------------- execution
def apply_dispatch(geom: DispatchGeometry, arrays, x, y, *, interpret: bool):
    """Traceable end-to-end executor body: pad → batched GEMM scatter →
    fused SpDMM → fused SpMM → slice, on ONE aliased canvas.  ``x`` (the
    densified operand) may be ``None`` when the plan has no dense-queue
    tasks.  Inlines into larger jitted programs (`models.gnn.compile_model`).
    """
    B, SM, SN = geom.B, geom.SM, geom.SN
    M_pad, N_pad = geom.m_pad, geom.n_pad
    z = jnp.zeros((M_pad, N_pad), dtype=jnp.float32)

    if geom.has_gemm:
        if x is None:
            raise ValueError("compiled dispatch: dense-queue tasks need the "
                             "densified x operand (got x=None)")
        rows, cols = arrays["gemm_rows"], arrays["gemm_cols"]
        x_p = jnp.pad(x, ((0, M_pad - geom.M), (0, 0)))
        y_p = jnp.pad(y, ((0, 0), (0, geom.nct * geom.tn - geom.N))
                      ).reshape(geom.K, geom.nct, geom.tn)
        if SN != geom.tn:
            y_p = jnp.pad(y_p, ((0, 0), (0, 0), (0, SN - geom.tn)))
        xs = x_p.reshape(geom.nrt, SM, geom.K)[rows]
        ys = jnp.moveaxis(y_p, 1, 0)[cols]
        z = ops.gemm_batch_scatter(xs, ys, rows, cols, z, interpret=interpret)

    if geom.has_spdmm or geom.has_spmm:
        ncb = geom.ncb
        y_pad = jnp.pad(y, ((0, ncb * B - geom.K),
                            (0, geom.nct * geom.tn - geom.N)))
        y_f = jnp.pad(y_pad.reshape(ncb * B, geom.nct, geom.tn),
                      ((0, 0), (0, 0), (0, SN - geom.tn))
                      ).reshape(ncb * B, geom.nct * SN)

    if geom.has_spdmm:
        z = ops.spdmm_fused(
            arrays["sp_pool"], y_f, arrays["sp_a_ids"], arrays["sp_y_rows"],
            arrays["sp_out_rows"], arrays["sp_out_cols"], arrays["sp_first"],
            block_size=B, bn=SN, m_pad=M_pad, interpret=interpret, z=z)

    if geom.has_spmm:
        y_blocks = ops.blockize(y_f, B)
        z = ops.spmm_fused(
            arrays["mm_pool"], y_blocks, arrays["mm_a_ids"],
            arrays["mm_y_ids"], arrays["mm_out_rows"], arrays["mm_out_cols"],
            arrays["mm_first"], block_size=B, m_pad=M_pad, n_pad=N_pad,
            interpret=interpret, z=z)

    return z[:geom.M, :geom.N]


@functools.partial(jax.jit, static_argnames=("geom", "interpret"))
def _run_dispatch(geom, arrays, x, y, *, interpret):
    return apply_dispatch(geom, arrays, x, y, interpret=interpret)


# Trace-cache observability: jax.jit caches per (geometry, operand signature);
# this mirror of that key set lets engines report honest trace hit counts.
_TRACE_SEEN: set = set()
_TRACE_LOCK = threading.Lock()


def _signature(geom, arrays, x, y, interpret):
    arr_sig = tuple(sorted((k, v.shape, str(v.dtype))
                           for k, v in arrays.items()))
    x_sig = None if x is None else (tuple(x.shape), str(x.dtype))
    return (geom, arr_sig, x_sig, tuple(y.shape), str(y.dtype), interpret)


def reset_trace_registry() -> None:
    """Forget which executor signatures were seen (tests/benchmarks).  Note
    jax's own jit cache is NOT cleared — after a reset the first call per
    signature is counted as a build again even though jax may reuse its
    trace; pair with ``jax.clear_caches()`` when that distinction matters."""
    with _TRACE_LOCK:
        _TRACE_SEEN.clear()


def execute_dispatch(d: CompiledDispatch, x, y, *, interpret: bool,
                     stats=None) -> jax.Array:
    """Run one compiled kernel: a single jitted call, zero host descriptor
    work.  ``stats`` (a ``CacheStats``) receives trace-cache accounting."""
    y = jnp.asarray(y)
    key = _signature(d.geom, d.arrays, x, y, interpret)
    with _TRACE_LOCK:
        hit = key in _TRACE_SEEN
        _TRACE_SEEN.add(key)
    if stats is not None:
        if hit:
            stats.trace_cache_hits += 1
        else:
            stats.trace_builds += 1
    return _run_dispatch(d.geom, d.arrays, x, y, interpret=interpret)
