"""Measured performance model — microbenchmark-calibrated Table I closed forms.

The paper's runtime mapping (Alg. 4) is only as good as its performance
model; Dynasparse's lesson is that dynamic mapping beats static thresholds
exactly when the model tracks the hardware it runs on.  ``VCK5000`` is
analytical by design (it reproduces the paper's tables), but the runtime
models (``TPUV5E`` and the other ``fallback=True`` entries of
``repro.core.perfmodel``) are hand-tuned guesses.  This module replaces the
guesses with measurements:

- :func:`calibrate` times the ACTUAL Pallas kernels the dispatcher issues —
  ``gemm_batch_scatter`` tiles (the dense queue), per-stored-block
  ``spdmm_fused``/``spmm_fused`` cost (the sparse queues), the on-device
  activation packer ``pack_activation_stripes``, and the per-launch
  dispatch floor — over a small shape/density sweep, then least-squares
  fits ``t = c0 + c1 * effective_MACs`` per engine and re-derives the
  :class:`~repro.core.perfmodel.HardwareModel` parameters (per-MAC rates,
  ``dispatch_overhead``, effective memory bandwidth) into a
  :class:`CalibratedModel`.
- The fitted bandwidth is cross-checked against
  :func:`repro.launch.roofline.hlo_cost` on the lowered XLA program of a
  reference GEMM (``roofline_bw_ratio`` — a consistency signal, ~O(1) when
  the fit and the HLO cost model agree about the same hardware).
- :func:`get_calibrated` persists the fit in a
  :class:`~repro.core.plancache.PlanCache` (and therefore in
  ``SharedPlanCache`` snapshots) keyed by (device kind, block, dtype, base
  model) with ``CacheStats.calib_builds/calib_hits`` accounting, plus an
  optional file snapshot (``REPRO_CALIBRATION_PATH`` — the CI cache
  artifact), so a restarted process replays ZERO measurements.

``DynasparseEngine(calibration="auto")`` resolves its analysis model through
this module whenever its hardware model is a ``fallback`` one; the Analyzer
and the compiled-path decline heuristics then follow measured device
timings instead of the guesses.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.perfmodel import HardwareModel
from repro.kernels import ops

# number of microbenchmark kernel timings taken by THIS process — the
# bench/test observable for "a restart replays zero measurements"
_MEASUREMENTS = 0


def measurement_count() -> int:
    return _MEASUREMENTS


def reset_measurement_count() -> None:
    global _MEASUREMENTS
    _MEASUREMENTS = 0


@dataclasses.dataclass(frozen=True)
class CalibratedModel(HardwareModel):
    """A :class:`HardwareModel` whose rates were FIT from measured kernel
    timings.  The Table I closed forms are unchanged — only the parameters
    move — so the Analyzer/Scheduler consume it transparently.  Extra
    fields carry the fit's provenance and quality so a decision made on a
    calibrated model is auditable."""
    backend: str = ""          # compat.backend_kind() at measurement time
    block: int = 8             # Pallas block size the sweep used
    dtype: str = "float32"
    base: str = ""             # fallback model the frequencies came from
    n_samples: int = 0         # timed kernel invocations behind the fit
    gemm_s_per_mac: float = 0.0     # fitted marginal costs (seconds)
    spdmm_s_per_mac: float = 0.0    # ...per EFFECTIVE (stored-block) MAC
    spmm_s_per_mac: float = 0.0
    pack_s_per_slot: float = 0.0    # activation packer marginal slot cost
    fit_residual: float = 0.0       # max relative RMS across the fits
    roofline_flops: float = 0.0     # hlo_cost of the cross-check GEMM
    roofline_bytes: float = 0.0
    roofline_bw_ratio: float = 0.0  # hlo-implied achieved bw / fitted bw


def calibration_key(base: HardwareModel, block: int, dtype: str) -> tuple:
    """(device kind, block, dtype, base name) — the persistence key.  The
    device kind comes first: measurements taken on one backend must never
    be replayed on another."""
    return (compat.backend_kind(), int(block), str(dtype), base.name)


# ------------------------------------------------------------ measurement
def _time(fn, *, repeats: int) -> float:
    """Min-of-repeats wall time of ``fn()`` after one warmup call (the
    warmup absorbs tracing/compilation, which is launch overhead's job to
    model only through the dispatch floor, not the marginal rates)."""
    global _MEASUREMENTS
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    _MEASUREMENTS += 1
    return best


def _measure_gemm(block: int, np_dtype, interpret: bool, repeats: int,
                  rng) -> list[dict]:
    """Dense-queue samples: ``gemm_batch_scatter`` with T canvas tiles —
    exactly the launch the compiled dispatch issues for the DTQ."""
    m = k = n = 4 * block
    out = []
    for T in (1, 2, 4):
        x = jnp.asarray(rng.normal(size=(T, m, k)).astype(np_dtype))
        y = jnp.asarray(rng.normal(size=(T, k, n)).astype(np_dtype))
        rows = jnp.arange(T, dtype=jnp.int32)
        cols = jnp.zeros(T, dtype=jnp.int32)
        z = jnp.zeros((T * m, n), jnp.float32)
        t = _time(lambda: ops.gemm_batch_scatter(
            x, y, rows, cols, z, interpret=interpret), repeats=repeats)
        out.append({"kind": "gemm", "macs": T * m * k * n, "t": t})
    return out


def _measure_spdmm(block: int, np_dtype, interpret: bool, repeats: int,
                   rng) -> list[dict]:
    """Sparse-queue samples: ``spdmm_fused`` over E stored-block entries —
    the per-stored-block cost the block-skip closed form needs."""
    B, bn, ncb = block, 4 * block, 4
    y = jnp.asarray(rng.normal(size=(ncb * B, bn)).astype(np_dtype))
    out = []
    for E in (4, 16, 48):
        pool = jnp.asarray(rng.normal(size=(E, B, B)).astype(np_dtype))
        ids = jnp.arange(E, dtype=jnp.int32)
        y_rows = jnp.asarray(np.arange(E, dtype=np.int32) % ncb)
        zeros = jnp.zeros(E, dtype=jnp.int32)
        first = jnp.ones(E, dtype=jnp.int32)
        t = _time(lambda: ops.spdmm_fused(
            pool, y, ids, y_rows, ids, zeros, first,
            block_size=B, bn=bn, m_pad=E * B, interpret=interpret),
            repeats=repeats)
        out.append({"kind": "spdmm", "macs": E * B * B * bn, "t": t})
    return out


def _measure_spmm(block: int, np_dtype, interpret: bool, repeats: int,
                  rng) -> list[dict]:
    """Sparse-queue samples: ``spmm_fused`` over E (A block, Y block)
    triples."""
    B = block
    y_pool = jnp.asarray(rng.normal(size=(8, B, B)).astype(np_dtype))
    out = []
    for E in (4, 16, 48):
        pool = jnp.asarray(rng.normal(size=(E, B, B)).astype(np_dtype))
        ids = jnp.arange(E, dtype=jnp.int32)
        y_ids = jnp.asarray(np.arange(E, dtype=np.int32) % 8)
        zeros = jnp.zeros(E, dtype=jnp.int32)
        first = jnp.ones(E, dtype=jnp.int32)
        t = _time(lambda: ops.spmm_fused(
            pool, y_pool, ids, y_ids, ids, zeros, first,
            block_size=B, m_pad=E * B, n_pad=B, interpret=interpret),
            repeats=repeats)
        out.append({"kind": "spmm", "macs": E * B * B * B, "t": t})
    return out


def _measure_pack(block: int, np_dtype, repeats: int, rng) -> list[dict]:
    """Activation-packer samples: the traceable
    ``pack_activation_stripes`` jitted alone, swept over slot counts."""
    B = block
    out = []
    for S, R, C, cap in ((2, 4, 4, 4), (4, 4, 8, 8)):
        x = jnp.asarray(rng.normal(size=(S * R * B, C * B)).astype(np_dtype))
        pk = jax.jit(functools.partial(
            ops.pack_activation_stripes, block=B, n_stripes=S, slot_rows=R,
            n_block_cols=C, capacity=cap, eps=0.0))
        t = _time(lambda: pk(x), repeats=repeats)
        out.append({"kind": "pack", "slots": S * cap, "t": t})
    return out


def _measure_dispatch_floor(block: int, np_dtype, interpret: bool,
                            repeats: int, rng) -> float:
    """Per-launch dispatch floor: the smallest possible kernel's wall time
    is almost entirely launch overhead."""
    B = block
    x = jnp.asarray(rng.normal(size=(1, B, B)).astype(np_dtype))
    y = jnp.asarray(rng.normal(size=(1, B, B)).astype(np_dtype))
    z = jnp.zeros((B, B), jnp.float32)
    idx = jnp.zeros(1, dtype=jnp.int32)
    return _time(lambda: ops.gemm_batch_scatter(
        x, y, idx, idx, z, interpret=interpret), repeats=repeats)


def _measure_membw(np_dtype, repeats: int) -> float:
    """Effective memory bandwidth from a jitted streaming op (read + write
    one large buffer)."""
    a = jnp.zeros((1024, 1024), np_dtype)
    f = jax.jit(lambda v: v + 1)
    t = _time(lambda: f(a), repeats=repeats)
    return 2.0 * a.size * a.dtype.itemsize / max(t, 1e-9)


def _fit_linear(samples: list[dict], xkey: str = "macs"
                ) -> tuple[float, float, float]:
    """Least-squares ``t = c0 + c1 * x`` with nonnegativity clamps; returns
    (c0, c1, relative RMS residual)."""
    t = np.array([s["t"] for s in samples], dtype=np.float64)
    x = np.array([s[xkey] for s in samples], dtype=np.float64)
    A = np.stack([np.ones_like(x), x], axis=1)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    c0, c1 = float(coef[0]), float(coef[1])
    if c1 <= 0.0:
        # overhead-dominated sweep: the marginal slope is below measurement
        # noise.  Attribute the largest sample's whole time as marginal
        # cost — a conservative upper bound — rather than fitting a free
        # (or negative-cost) engine that the Analyzer would then always pick.
        i = int(np.argmax(x))
        c0, c1 = 0.0, float(t[i] / x[i])
    c0 = max(c0, 0.0)
    c1 = max(c1, 1e-18)
    pred = c0 + c1 * x
    resid = float(np.sqrt(np.mean(((pred - t) / np.maximum(t, 1e-12)) ** 2)))
    return c0, c1, resid


def _roofline_crosscheck(np_dtype, membw_fit: float, repeats: int
                         ) -> tuple[float, float, float]:
    """Lower a reference GEMM, cost it with ``roofline.hlo_cost``, time it,
    and compare the HLO-implied achieved bandwidth with the fitted one.
    Never fatal — a backend whose HLO text the parser cannot read reports
    zeros instead of failing calibration."""
    try:
        from repro.launch import roofline
        a = jnp.zeros((256, 256), np_dtype)
        b = jnp.zeros((256, 256), np_dtype)
        fn = jax.jit(lambda u, v: jnp.dot(
            u, v, preferred_element_type=jnp.float32))
        cost = roofline.lowered_cost(fn, a, b)
        t = _time(lambda: fn(a, b), repeats=repeats)
        implied_bw = float(cost["bytes"]) / max(t, 1e-12)
        return (float(cost["flops"]), float(cost["bytes"]),
                implied_bw / max(membw_fit, 1e-9))
    except Exception:
        return 0.0, 0.0, 0.0


def calibrate(base: HardwareModel, *, block: int = 8,
              dtype: str = "float32", interpret: bool | None = None,
              repeats: int = 2, seed: int = 0) -> CalibratedModel:
    """Run the microbenchmark sweep ONCE and fit a :class:`CalibratedModel`.

    The base model contributes its frequencies (rates are re-derived from
    the fitted marginal costs at those frequencies, so the closed forms
    keep their Table I shape) and its ``skip_block`` granularity; every
    rate, the dispatch overhead and the memory bandwidth are replaced by
    measurements.  ``n_sparse_units`` becomes 1 — the measured sparse path
    is one fused kernel stream, not the paper's 8 ALU arrays.
    """
    interpret = ops.default_interpret() if interpret is None else interpret
    np_dtype = np.dtype(dtype)
    rng = np.random.default_rng(seed)
    n0 = measurement_count()

    gemm_s = _measure_gemm(block, np_dtype, interpret, repeats, rng)
    spdmm_s = _measure_spdmm(block, np_dtype, interpret, repeats, rng)
    spmm_s = _measure_spmm(block, np_dtype, interpret, repeats, rng)
    pack_s = _measure_pack(block, np_dtype, repeats, rng)
    floor = _measure_dispatch_floor(block, np_dtype, interpret, repeats, rng)
    membw = _measure_membw(np_dtype, repeats)

    c0_g, c1_g, r_g = _fit_linear(gemm_s)
    c0_d, c1_d, r_d = _fit_linear(spdmm_s)
    c0_m, c1_m, r_m = _fit_linear(spmm_s)
    _, c1_p, r_p = _fit_linear(pack_s, xkey="slots")
    # the dispatch floor and the fitted intercepts estimate the same launch
    # bubble from different sweeps; take the most pessimistic
    overhead = max(floor, c0_g, c0_d, c0_m)

    rl_flops, rl_bytes, rl_ratio = _roofline_crosscheck(
        np_dtype, membw, repeats)

    return CalibratedModel(
        name=(f"{base.name}+calib[{compat.backend_kind()}"
              f",b{block},{dtype}]"),
        f_dense=base.f_dense,
        dense_macs_per_cycle=1.0 / (c1_g * base.f_dense),
        f_sparse=base.f_sparse,
        spdmm_macs_per_cycle=1.0 / (c1_d * base.f_sparse),
        spmm_macs_per_cycle=1.0 / (c1_m * base.f_sparse),
        n_sparse_units=1,
        mem_bw=membw,
        bytes_per_elem=int(np_dtype.itemsize),
        dispatch_overhead=overhead,
        skip_block=base.skip_block,
        fallback=False,
        calibrated=True,
        backend=compat.backend_kind(),
        block=int(block),
        dtype=str(dtype),
        base=base.name,
        n_samples=measurement_count() - n0,
        gemm_s_per_mac=c1_g,
        spdmm_s_per_mac=c1_d,
        spmm_s_per_mac=c1_m,
        pack_s_per_slot=c1_p,
        fit_residual=float(max(r_g, r_d, r_m, r_p)),
        roofline_flops=rl_flops,
        roofline_bytes=rl_bytes,
        roofline_bw_ratio=rl_ratio,
    )


# ------------------------------------------------------------- persistence
SNAPSHOT_ENV = "REPRO_CALIBRATION_PATH"


def save_snapshot(path: str, models: dict[tuple, CalibratedModel]) -> None:
    """Write a calibration snapshot (the CI cache artifact).  Plain pickle
    of {calibration_key: CalibratedModel} — every field is a host scalar.

    Atomic: pickled to a same-directory temp file then ``os.replace``d into
    place, so a crash mid-save can never leave a truncated snapshot for the
    next process to choke on (it keeps the previous snapshot instead)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            pickle.dump({"version": 1, "models": dict(models)}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load_snapshot(path: str) -> dict[tuple, CalibratedModel]:
    with open(path, "rb") as f:
        payload = pickle.load(f)
    if payload.get("version") != 1:
        raise ValueError(
            f"unsupported calibration snapshot version "
            f"{payload.get('version')!r}")
    return dict(payload["models"])


def get_calibrated(cache, base: HardwareModel, *, block: int = 8,
                   dtype: str = "float32", interpret: bool | None = None,
                   repeats: int = 2,
                   snapshot_path: str | None = None) -> CalibratedModel:
    """Get-or-measure the calibration for (device kind, block, dtype, base).

    Resolution order: the plan cache (``calib_hits`` — zero work), then the
    file snapshot (``snapshot_path`` or ``$REPRO_CALIBRATION_PATH`` — zero
    measurements, counted as a build), then a fresh :func:`calibrate` sweep
    whose result is written back to both.  A ``SharedPlanCache.save``/
    ``load`` round-trip therefore replays restarts with zero re-measures.
    """
    key = calibration_key(base, block, dtype)

    def compute() -> CalibratedModel:
        path = snapshot_path or os.environ.get(SNAPSHOT_ENV)
        if path and os.path.exists(path):
            try:
                m = load_snapshot(path).get(key)
                if m is not None:
                    return m
            except Exception as exc:
                # unreadable (corrupt/truncated/wrong-version) snapshot:
                # a logged cold start — fall through to measuring.  The
                # counter makes the degradation observable instead of a
                # silently slower restart.
                cache.stats.snapshot_errors += 1
                logging.getLogger(__name__).warning(
                    "calibration snapshot %s unusable (%s: %s) — "
                    "re-measuring", path, type(exc).__name__, exc)
        m = calibrate(base, block=block, dtype=dtype, interpret=interpret,
                      repeats=repeats)
        if path:
            try:
                snap = load_snapshot(path) if os.path.exists(path) else {}
            except Exception:
                snap = {}
            try:
                snap[key] = m
                save_snapshot(path, snap)
            except Exception:
                pass   # read-only FS: the in-process cache still has it
        return m

    return cache.calibration(key, compute)
