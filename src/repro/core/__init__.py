"""The paper's primary contribution: dynamic sparsity-exploiting GNN
inference runtime for a heterogeneous (dense-engine + sparse-engine) target.

Pipeline: sparsity measurement -> 2-D task partitioning -> Analyzer
(perf-model queue assignment, Alg. 4) -> Scheduler (engine dispatch) ->
primitives (Pallas GEMM / SpDMM / SpMM).
"""
from repro.core.engine import DynasparseEngine, EngineReport
from repro.core.perfmodel import (HardwareModel, TaskShape, VCK5000,
                                  VCK5000_384, TPUV5E, t_dense, t_sparse)
from repro.core.plancache import KernelPlan, PlanCache
from repro.core.primitives import SparseCOO

__all__ = [
    "DynasparseEngine", "EngineReport", "HardwareModel", "TaskShape",
    "VCK5000", "VCK5000_384", "TPUV5E", "t_dense", "t_sparse", "SparseCOO",
    "KernelPlan", "PlanCache",
]
