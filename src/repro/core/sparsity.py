"""On-device density analysis (the measurement half of the paper's Analyzer).

The VCK5000 runtime reads per-partition densities on the ARM APU.  Here the
densities are computed on-device with cheap reductions and only the tiny
per-stripe density vectors are transferred to host once per kernel — this is
the piece that makes the sparsity exploitation *dynamic*: intermediate feature
matrices (post-ReLU) are measured as they are produced, not at compile time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("eps",))
def density(x: jax.Array, eps: float = 0.0) -> jax.Array:
    """Fraction of nonzero elements (paper §II-B: density = nnz / size)."""
    nz = jnp.sum(jnp.abs(x) > eps)
    return nz / x.size


@functools.partial(jax.jit, static_argnames=("tile", "axis", "eps"))
def stripe_density(x: jax.Array, tile: int, axis: int = 0,
                   eps: float = 0.0) -> jax.Array:
    """Density of each row-stripe (axis=0) or col-stripe (axis=1).

    Stripes are the task operands of Eq. 3: ``X_{i,:}`` / ``Y_{:,j}``.
    Ragged tails count only logical elements.  ``eps`` is the same nonzero
    tolerance as :func:`density`, so the Analyzer's task assignment and the
    reported kernel density agree on near-zero (post-ReLU) values.
    """
    m = x.shape[axis]
    n_stripes = -(-m // tile)
    pad = n_stripes * tile - m
    widths = [(0, 0), (0, 0)]
    widths[axis] = (0, pad)
    xp = jnp.pad(x, widths)
    if axis == 0:
        xp = xp.reshape(n_stripes, tile, x.shape[1])
        nz = jnp.sum(jnp.abs(xp) > eps, axis=(1, 2))
        sizes = jnp.full((n_stripes,), tile * x.shape[1])
        sizes = sizes.at[-1].set((m - (n_stripes - 1) * tile) * x.shape[1])
    else:
        xp = xp.reshape(x.shape[0], n_stripes, tile)
        nz = jnp.sum(jnp.abs(xp) > eps, axis=(0, 2))
        sizes = jnp.full((n_stripes,), tile * x.shape[0])
        sizes = sizes.at[-1].set((m - (n_stripes - 1) * tile) * x.shape[0])
    return nz / sizes


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "eps"))
def tile_density(x: jax.Array, tile_m: int, tile_n: int,
                 eps: float = 0.0) -> jax.Array:
    """(n_row_tiles, n_col_tiles) grid of per-tile densities."""
    m, n = x.shape
    nrt, nct = -(-m // tile_m), -(-n // tile_n)
    xp = jnp.pad(x, ((0, nrt * tile_m - m), (0, nct * tile_n - n)))
    xp = xp.reshape(nrt, tile_m, nct, tile_n)
    nz = jnp.sum(jnp.abs(xp) > eps, axis=(1, 3))
    return nz / (tile_m * tile_n)


def sketch_col_density(y: jax.Array, tile_n: int, *, max_rows: int = 256,
                       eps: float = 0.0) -> np.ndarray:
    """Cheap per-col-stripe density ESTIMATE from a strided row sample.

    The serving path revalidates a cached plan's measured Y-column densities
    on every hit; a full ``stripe_density`` scan would erase much of the
    amortization on large feature matrices, so the sketch reads at most
    ``max_rows`` evenly-strided rows — O(max_rows · N) instead of O(K · N).
    With ``K <= max_rows`` it degenerates to the exact measurement.
    """
    K = y.shape[0]
    if K > max_rows:
        stride = -(-K // max_rows)
        y = y[::stride]
    return np.asarray(stripe_density(y, tile_n, axis=1, eps=eps))


def density_drift(sketch: np.ndarray, reference: np.ndarray) -> float:
    """Max per-stripe absolute density gap between a sketch and the densities
    a cached plan was built from.  Incomparable shapes (the tile geometry
    changed) count as infinite drift — always replan."""
    a = np.asarray(sketch, dtype=np.float64)
    b = np.asarray(reference, dtype=np.float64)
    if a.shape != b.shape:
        return float("inf")
    if a.size == 0:
        return 0.0
    return float(np.max(np.abs(a - b)))


def block_density(x: np.ndarray, block: int, eps: float = 0.0) -> float:
    """Fraction of non-zero B x B blocks — the TPU-native α (tile-level skip
    granularity; see DESIGN.md §2)."""
    t = np.asarray(tile_density(jnp.asarray(x), block, block, eps=eps))
    return float(np.mean(t > 0))
