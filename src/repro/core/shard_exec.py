"""Sharded compiled dispatch — one per-shard program under ``shard_map``.

A device-placed plan (``KernelPlan.placement`` from
:func:`repro.core.analyzer.analyze_sharded`) lowers here into a
:class:`ShardedDispatch`: the same descriptor arrays a
:class:`~repro.core.dispatch.CompiledDispatch` carries, but banded by device
(leading device axis, contiguous LOCAL row numbering inside each band) and
executed by ONE ``shard_map``-wrapped :func:`~repro.core.dispatch.apply_prepared`
body on a 1-D ``("data",)`` mesh.  Mesh size 1 is the degenerate case of the
same code path — there is no single-device fork — and the result is
bit-identical to the unsharded executor (see below).

Uniform shard geometry via a GHOST row-tile
-------------------------------------------
``shard_map`` needs every shard to run the identical program on
identically-shaped operands, but min-makespan bands are ragged (different
stripe counts per device; stripe counts need not divide the device count).
Each shard therefore gets ``nrt_local = max_band_tiles + 1`` row tiles: real
bands occupy a prefix, and the extra GHOST tile absorbs all descriptor
padding needed to equalize per-device entry counts:

- GEMM pads address output tile ``(nrt_local - 1, 0)`` — the gathered X slab
  for the ghost tile is all zeros, so the scatter overwrites the ghost tile
  with zeros;
- SpDMM / SpMM pads reference an appended all-zero pool block with
  ``first = 0`` at the ghost tile's first block-row, so they ACCUMULATE
  ``0 · Y`` into an already-zero canvas block (the kernels' ``first == 1``
  zero-init / ``first == 0`` accumulate semantics make this an exact bitwise
  no-op — the same sentinel-zero-block idiom ``kernels/spmm.py`` uses for its
  own padding triples).

Owned-operand sharding with halo exchange (``operand_sharding="halo"``)
-----------------------------------------------------------------------
By default the dense operand Y no longer enters the program replicated.
Lowering runs a per-band COLUMN-SUPPORT analysis over the descriptors it
just built (SpDMM entries name their Y block-rows directly; SpMM triples
encode them in ``y_ids``; GEMM bands read everything → replicated
fallback), emits one :class:`repro.core.halo.ColumnSupport` per device, and
compiles a static ring-exchange schedule (:func:`repro.core.halo.
build_exchange`).  Y is split by block-row OWNERSHIP outside the program
(each shard's ``in_spec P("data")`` slab holds only its owned rows), the
``shard_map`` body first runs ``nd - 1`` ``ppermute`` rounds copying halo
blocks into a local ``(L + 1)`` slot owned+halo buffer, and the SpDMM/SpMM
descriptors — rewritten at lowering time from global block-rows to local
buffer slots — feed the very same fused kernels.  Per-device dense-operand
memory drops from ``O(ncb)`` block-rows to ``O(max_own + max_support)``;
a fully block-diagonal graph has empty halos and emits ZERO collectives.
``operand_sharding="replicate"`` keeps the PR 8 layout as the bitwise
correctness oracle.

Bit-identity with the unsharded executor holds because every REAL output
block receives exactly the contribution sequence it receives globally: the
per-band entry sort (local ``out_row`` = global ``out_row`` − band offset)
preserves the global per-block ordering, the halo exchange is pure data
movement of the rows ``_stripe_padded_y`` lays out globally (descriptor
entry ORDER never changes, only Y indices are remapped to local slots), and
float accumulation order per block is unchanged.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import dispatch as _dispatch
from repro.core import halo as _halo

OPERAND_SHARDINGS = ("halo", "replicate")


@dataclasses.dataclass
class ShardedDispatch:
    """Device-banded instruction stream of one placed kernel.

    ``geom`` is the per-shard LOCAL geometry (uniform across devices:
    ``nrt = max_band_tiles + 1`` with the ghost tile, ``M = m_pad``).
    ``arrays`` mirrors :class:`~repro.core.dispatch.CompiledDispatch.arrays`
    with a leading device axis — in halo mode that includes the exchange
    schedule index arrays (``hx_*``), so snapshot restore re-uploads them
    with everything else.  ``band_rows[d]`` is the count of logical output
    rows device ``d`` owns (the final assembly concatenates
    ``z[d, :band_rows[d]]``).  ``halo`` is the static
    :class:`~repro.core.halo.HaloGeometry` (``None`` → replicated operand),
    ``supports`` the per-device column supports, and ``operand_bytes`` the
    analytic per-device dense-operand memory accounting
    (``dispatch_stats()`` aggregates it).
    """
    geom: _dispatch.DispatchGeometry
    n_devices: int
    band_starts: tuple[int, ...]
    band_rows: tuple[int, ...]
    M: int                             # global logical row count
    arrays: dict[str, jax.Array]
    fingerprint: str
    supports: tuple = ()
    halo: object = None                # _halo.HaloGeometry | None
    operand_sharding: str = "replicate"
    operand_bytes: dict = dataclasses.field(default_factory=dict)

    @property
    def needs_x(self) -> bool:
        return self.geom.has_gemm


def _pool_dtype(stripes):
    for s in stripes.values():
        return np.asarray(s.blocks).dtype
    return np.dtype(np.float32)


def _band_tasks(tasks, placement, d):
    lo, hi = placement.band_starts[d], placement.band_starts[d + 1]
    return [dataclasses.replace(t, i=t.i - lo) for t in tasks if lo <= t.i < hi]


def _column_supports(per_gemm, per_spdmm, per_spmm, own_starts, ncb, nyc):
    """Per-device :class:`~repro.core.halo.ColumnSupport` from the lowered
    descriptor arrays: SpDMM entries carry Y block-rows in ``y_rows``, SpMM
    triples carry ``block_row * nyc + block_col`` in ``y_ids``, and a band
    with real GEMM tasks reads the whole operand (replicated fallback)."""
    nd = len(own_starts) - 1
    supports = []
    for d in range(nd):
        full = len(per_gemm[d]) > 0
        if full:
            read = set(range(ncb))
        else:
            read = set()
            e = per_spdmm[d][1]
            if e is not None:
                read.update(int(g) for g in np.unique(e[1]))
            e = per_spmm[d][1]
            if e is not None:
                read.update(int(g) for g in np.unique(e[1] // nyc))
        own = range(own_starts[d], own_starts[d + 1])
        supports.append(_halo.ColumnSupport(
            own_start=own_starts[d], own_stop=own_starts[d + 1],
            halo=tuple(sorted(read - set(own))), full=full))
    return tuple(supports)


def _localize_entries(supports, per_spdmm, per_spmm, ncb, nyc):
    """Rewrite Y indices from GLOBAL block-rows to LOCAL owned+halo buffer
    slots, per device.  Entry order (hence accumulation order) untouched."""
    sp_out, mm_out = [], []
    for cs, (sp_pool, sp_e), (mm_pool, mm_e) in zip(
            supports, per_spdmm, per_spmm):
        lut = np.zeros(ncb, np.int64)
        for slot, g in enumerate(cs.local_blocks()):
            lut[g] = slot
        if sp_e is not None:
            sp_e = (sp_e[0], lut[sp_e[1]], sp_e[2], sp_e[3], sp_e[4])
        if mm_e is not None:
            mm_e = (mm_e[0], lut[mm_e[1] // nyc] * nyc + mm_e[1] % nyc,
                    mm_e[2], mm_e[3], mm_e[4])
        sp_out.append((sp_pool, sp_e))
        mm_out.append((mm_pool, mm_e))
    return sp_out, mm_out


def build_sharded_dispatch(part, stq, dtq, stripes, placement,
                           *, block: int, eps: float = 0.0,
                           fingerprint: str = "",
                           operand_sharding: str = "halo",
                           faults: object = None) -> ShardedDispatch | None:
    """Lower a device-placed plan into a :class:`ShardedDispatch`.

    Same O(nnz blocks) vectorized-numpy cost as
    :func:`~repro.core.dispatch.build_dispatch`, paid once per (structure,
    assignment, mesh geometry, operand-sharding mode); ``None`` when the
    canvas geometry cannot take the in-place index maps (caller falls back
    to the eager path, which is placement-agnostic and already correct).
    """
    if operand_sharding not in OPERAND_SHARDINGS:
        raise ValueError(f"operand_sharding must be one of "
                         f"{OPERAND_SHARDINGS}, got {operand_sharding!r}")
    if faults is not None:
        faults.probe("shard_lower", detail=f"shard:{part.name}")
    slots = _dispatch.canvas_slots(part, block)
    if slots is None:
        return None
    SM, SN = slots
    B = block
    R, C = SM // B, SN // B
    nd = placement.n_devices
    bs = placement.band_starts
    max_band = max(placement.band_sizes()) if nd else 0
    nrt_l = max_band + 1                       # +1 ghost tile for padding
    ghost_row = (nrt_l - 1) * R                # first block-row of the ghost

    band_rows = tuple(
        sum(part.row_extent(i) for i in placement.stripes_of(d))
        for d in range(nd))

    per_gemm, per_spdmm, per_spmm = [], [], []
    for d in range(nd):
        lo = bs[d]
        local_stripes = {i - lo: stripes[i] for i in placement.stripes_of(d)
                         if i in stripes}
        g = _band_tasks(dtq, placement, d)
        sp = _band_tasks([t for t in stq if t.primitive != "SpMM"],
                         placement, d)
        mm = _band_tasks([t for t in stq if t.primitive == "SpMM"],
                         placement, d)
        per_gemm.append(g)

        if sp:
            offsets, pool = _dispatch._stripe_pool(sp, local_stripes)
            per_spdmm.append((np.asarray(pool),
                              _dispatch.spdmm_entry_arrays(
                                  sp, local_stripes, offsets, R)))
        else:
            per_spdmm.append((np.zeros((0, B, B), _pool_dtype(stripes)),
                              None))

        if mm:
            offsets, pool = _dispatch._stripe_pool(mm, local_stripes)
            per_spmm.append((np.asarray(pool),
                             _dispatch._spmm_dense_y_triples(
                                 mm, part, local_stripes, offsets, R, C,
                                 n_y_block_cols=part.n_col_tiles * C)))
        else:
            per_spmm.append((np.zeros((0, B, B), _pool_dtype(stripes)),
                             None))

    n_gemm = max((len(g) for g in per_gemm), default=0)

    ncb = -(-part.K // B)
    nyc = part.n_col_tiles * C                 # Y pool blocks per block-row
    supports: tuple = ()
    hg = None
    hx_arrays: dict[str, np.ndarray] = {}
    if operand_sharding == "halo":
        own_starts = _halo.ownership_starts(part.M, part.K, part.tile_m,
                                            bs, B)
        supports = _column_supports(per_gemm, per_spdmm, per_spmm,
                                    own_starts, ncb, nyc)
        per_spdmm, per_spmm = _localize_entries(supports, per_spdmm,
                                                per_spmm, ncb, nyc)
        hg, own_dst, hx_src, hx_dst, gather = _halo.build_exchange(
            supports, own_starts, gather=n_gemm > 0)
        hx_arrays = {"hx_own_dst": own_dst, "hx_src": hx_src,
                     "hx_dst": hx_dst}
        if gather is not None:
            hx_arrays["hx_gather"] = gather

    n_sp = max((0 if e is None else len(e[0]) for _, e in per_spdmm),
               default=0)
    n_mm = max((0 if e is None else len(e[0]) for _, e in per_spmm),
               default=0)

    geom = _dispatch.DispatchGeometry(
        M=nrt_l * SM, K=part.K, N=part.N, tm=part.tile_m, tn=part.tile_n,
        SM=SM, SN=SN, B=B, nrt=nrt_l, nct=part.n_col_tiles,
        has_gemm=n_gemm > 0, has_spdmm=n_sp > 0, has_spmm=n_mm > 0,
        eps=eps)

    arrays: dict[str, jax.Array] = {
        k: jnp.asarray(v) for k, v in hx_arrays.items()}

    if n_gemm:
        rows = np.full((nd, n_gemm), nrt_l - 1, dtype=np.int32)
        cols = np.zeros((nd, n_gemm), dtype=np.int32)
        for d, g in enumerate(per_gemm):
            rows[d, :len(g)] = [t.i for t in g]
            cols[d, :len(g)] = [t.j for t in g]
        arrays["gemm_rows"] = jnp.asarray(rows)
        arrays["gemm_cols"] = jnp.asarray(cols)

    def _stack_section(per_dev, n_entries, names, pad_cols):
        """Pad each device's (pool, entry-arrays) to common shapes and
        stack.  ``pad_cols[k]`` gives the pad value per entry column as a
        function of the padded pool length."""
        pool_len = max(len(p) for p, _ in per_dev) + 1   # +1 zero sentinel
        pools, columns = [], [[] for _ in names]
        for pool, entries in per_dev:
            pools.append(np.concatenate(
                [pool, np.zeros((pool_len - len(pool),) + pool.shape[1:],
                                pool.dtype)], axis=0))
            cols = (entries if entries is not None
                    else tuple(np.zeros(0, np.int32) for _ in names))
            pad_n = n_entries - len(cols[0])
            for k, c in enumerate(cols):
                columns[k].append(np.concatenate(
                    [c, np.full(pad_n, pad_cols[k](pool_len),
                                dtype=np.int32)]))
        out = {"pool": jnp.asarray(np.stack(pools))}
        for k, name in enumerate(names):
            out[name] = jnp.asarray(np.stack(columns[k]).astype(np.int32))
        return out

    if n_sp:
        sec = _stack_section(
            per_spdmm, n_sp,
            ("a_ids", "y_rows", "out_rows", "out_cols", "first"),
            # pads: zero-sentinel A block × Y row 0 → ghost block, first=0
            # (in halo mode Y row 0 is local slot 0 — any resident block
            # works: a zero A block accumulates an exact bitwise no-op)
            (lambda pl: pl - 1, lambda pl: 0, lambda pl: ghost_row,
             lambda pl: 0, lambda pl: 0))
        arrays["sp_pool"] = sec["pool"]
        for name in ("a_ids", "y_rows", "out_rows", "out_cols", "first"):
            arrays[f"sp_{name}"] = sec[name]

    if n_mm:
        sec = _stack_section(
            per_spmm, n_mm,
            ("a_ids", "y_ids", "out_rows", "out_cols", "first"),
            (lambda pl: pl - 1, lambda pl: 0, lambda pl: ghost_row,
             lambda pl: 0, lambda pl: 0))
        arrays["mm_pool"] = sec["pool"]
        for name in ("a_ids", "y_ids", "out_rows", "out_cols", "first"):
            arrays[f"mm_{name}"] = sec[name]

    width = part.n_col_tiles * SN
    if operand_sharding == "halo":
        op_bytes = _halo.operand_bytes(supports, hg, B, width)
    else:
        bb = B * width * 4
        op_bytes = {"mode": "replicate", "per_device": [
            {"owned_bytes": 0, "halo_bytes": 0, "fallback_bytes": ncb * bb,
             "full": True} for _ in range(nd)],
            "owned_bytes": 0, "halo_bytes": 0,
            "fallback_bytes": nd * ncb * bb,
            "halo_per_device_bytes": ncb * bb,
            "replicated_per_device_bytes": ncb * bb}

    return ShardedDispatch(geom=geom, n_devices=nd, band_starts=tuple(bs),
                           band_rows=band_rows, M=part.M, arrays=arrays,
                           fingerprint=fingerprint, supports=supports,
                           halo=hg, operand_sharding=operand_sharding,
                           operand_bytes=op_bytes)


def _x_slabs(geom, band_rows, x):
    """Per-band X slabs padded to the uniform shard height."""
    slabs, row0 = [], 0
    for r in band_rows:
        sl = jax.lax.slice_in_dim(x, row0, row0 + r, axis=0)
        slabs.append(jnp.pad(sl, ((0, geom.m_pad - r), (0, 0))))
        row0 += r
    return jnp.stack(slabs)


def _y_owned_slabs(geom, halo, y):
    """Owned block-row slabs of the stripe-padded operand, padded to
    ``max_own`` so every shard's ``in_spec P("data")`` slice is uniform."""
    B = geom.B
    W = geom.nct * geom.SN
    yb = _dispatch._stripe_padded_y(geom, y).reshape(geom.ncb, B, W)
    slabs = []
    for d in range(halo.n_devices):
        sl = yb[halo.own_starts[d]:halo.own_starts[d + 1]]
        slabs.append(jnp.pad(sl, ((0, halo.max_own - sl.shape[0]),
                                  (0, 0), (0, 0))))
    return jnp.stack(slabs)


def apply_sharded(geom, band_rows, arrays, x, y, *, mesh, interpret: bool,
                  halo=None):
    """Traceable sharded executor body: slab X per band (and, in halo mode,
    slab Y per OWNER) → ``shard_map`` the shared
    :func:`~repro.core.dispatch.apply_prepared` body → concatenate each
    band's logical rows.  Inlines into larger jitted programs
    (``models.gnn.compile_model``), exactly like the unsharded body."""
    nd = len(band_rows)
    y = jnp.asarray(y)

    if geom.has_gemm and x is None:
        raise ValueError("sharded dispatch: dense-queue tasks need the "
                         "densified x operand (got x=None)")

    if halo is None:
        # Replicated-operand oracle: Y enters every shard whole.
        def shard_body(local, x_l, y_rep):
            return _dispatch.apply_dispatch(geom, local, x_l, y_rep,
                                            interpret=interpret)
        y_in, y_spec = y, P()
    else:
        B, W = geom.B, geom.nct * geom.SN

        def shard_body(local, x_l, y_own):
            ybuf = _halo.exchange(local, y_own, halo)
            y_fl = ybuf.reshape((halo.L + 1) * B, W)
            y_pl = None
            if geom.has_gemm:
                y_pl = ybuf[local["hx_gather"]].reshape(
                    geom.ncb * B, geom.nct, geom.SN)[:geom.K]
            return _dispatch.apply_prepared(geom, local, x_l, y_fl, y_pl,
                                            interpret=interpret)
        y_in, y_spec = _y_owned_slabs(geom, halo, y), P("data")

    if geom.has_gemm:
        x_sh = _x_slabs(geom, band_rows, jnp.asarray(x))

        def body(arrs, xs, yy):
            local = {k: v[0] for k, v in arrs.items()}
            return shard_body(local, xs[0],
                              yy if halo is None else yy[0])[None]

        f = compat.shard_map(body, mesh=mesh,
                             in_specs=(P("data"), P("data"), y_spec),
                             out_specs=P("data"))
        zs = f(arrays, x_sh, y_in)
    else:
        def body(arrs, yy):
            local = {k: v[0] for k, v in arrs.items()}
            return shard_body(local, None,
                              yy if halo is None else yy[0])[None]

        f = compat.shard_map(body, mesh=mesh,
                             in_specs=(P("data"), y_spec),
                             out_specs=P("data"))
        zs = f(arrays, y_in)

    parts = [zs[d, :band_rows[d]] for d in range(nd) if band_rows[d]]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("geom", "band_rows", "mesh", "interpret",
                                    "halo"))
def _run_sharded(geom, band_rows, arrays, x, y, *, mesh, interpret,
                 halo=None):
    return apply_sharded(geom, band_rows, arrays, x, y,
                         mesh=mesh, interpret=interpret, halo=halo)


def _shard_signature(sd, x, y, mesh, interpret):
    arr_sig = tuple(sorted((k, v.shape, str(v.dtype))
                           for k, v in sd.arrays.items()))
    x_sig = None if x is None else (tuple(x.shape), str(x.dtype))
    return ("shard", sd.geom, sd.band_rows, int(np.prod(mesh.devices.shape)),
            sd.halo, arr_sig, x_sig, tuple(y.shape), str(y.dtype), interpret)


def execute_sharded(sd: ShardedDispatch, x, y, *, mesh, interpret: bool,
                    stats=None, faults=None) -> jax.Array:
    """Run one sharded compiled kernel: a single jitted call, zero host
    descriptor work.  Shares the trace registry with the unsharded executor
    so ``CacheStats`` trace accounting stays one ledger."""
    if faults is not None:
        faults.probe("shard_exec",
                     detail=f"nd:{sd.n_devices}:{sd.operand_sharding}")
    y = jnp.asarray(y)
    key = _shard_signature(sd, x, y, mesh, interpret)
    with _dispatch._TRACE_LOCK:
        hit = key in _dispatch._TRACE_SEEN
        _dispatch._TRACE_SEEN.add(key)
    if stats is not None:
        if hit:
            stats.trace_cache_hits += 1
        else:
            stats.trace_builds += 1
    return _run_sharded(sd.geom, sd.band_rows, sd.arrays, x, y,
                        mesh=mesh, interpret=interpret, halo=sd.halo)
