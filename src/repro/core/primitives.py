"""Functional compute primitives used by the engine.

The numerical result of a kernel is primitive-independent (GEMM, SpDMM and
SpMM all compute Z = X·Y); the primitive choice decides *time* and *data
movement*.  The engine therefore computes results through the fastest
functionally-equivalent path for the current backend:

- TPU / tests: the Pallas kernels via ``scheduler.execute_plan``;
- CPU at graph scale: a COO segment-sum SpDMM (adjacency is far too large to
  densify) and plain ``jnp.dot`` for dense operands.

``SparseCOO`` is the storage format of the paper's BufferA (Alg. 2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseCOO:
    """COO sparse matrix (rows sorted; the paper's BufferA layout).

    ``tag`` marks the matrix role ("adjacency" / "features" / "generic") —
    used by the benchmark harness's Table V accounting, which must be able to
    exploit adjacency sparsity while treating feature matrices as dense.
    """
    shape: Tuple[int, int]
    rows: jax.Array   # (nnz,) int32
    cols: jax.Array   # (nnz,) int32
    vals: jax.Array   # (nnz,) float
    tag: str = "generic"

    def tree_flatten(self):
        return (self.rows, self.cols, self.vals), (self.shape, self.tag)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        shape, tag = aux
        rows, cols, vals = leaves
        return cls(shape, rows, cols, vals, tag)

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        return self.nnz / (self.shape[0] * self.shape[1])

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.asarray(self.vals).dtype)
        np.add.at(out, (np.asarray(self.rows), np.asarray(self.cols)),
                  np.asarray(self.vals))
        return out

    def row_stripe_density(self, tile_m: int, eps: float = 0.0) -> np.ndarray:
        """α(X_{i,:}) per row-stripe, from nnz counts (host, O(nnz)).

        ``eps > 0`` drops stored values with ``|v| <= eps`` from the count,
        matching the dense :func:`repro.core.sparsity.stripe_density`
        tolerance; ``eps == 0`` counts every stored entry (nnz semantics).
        """
        n_stripes = -(-self.shape[0] // tile_m)
        rows = np.asarray(self.rows)
        if eps > 0.0:
            rows = rows[np.abs(np.asarray(self.vals)) > eps]
        counts = np.bincount(rows // tile_m,
                             minlength=n_stripes).astype(np.float64)
        sizes = np.full(n_stripes, tile_m * self.shape[1], dtype=np.float64)
        tail = self.shape[0] - (n_stripes - 1) * tile_m
        sizes[-1] = tail * self.shape[1]
        return counts / sizes


@functools.partial(jax.jit, static_argnames=("n_rows", "chunk"))
def coo_spdmm(rows: jax.Array, cols: jax.Array, vals: jax.Array,
              h: jax.Array, n_rows: int, chunk: int = 1_000_000) -> jax.Array:
    """Z = A @ H with A in COO — scatter-gather SpDMM (paper Alg. 2).

    Gather (Pairing Unit): ``h[cols]``; Update (Multiply Unit): ``vals * h``;
    Reduce (Accumulator): ``segment_sum`` into output rows.  Chunked over
    edges with ``lax.scan`` so the gathered intermediate never exceeds
    ``chunk x d`` — the BufferG working-set bound.
    """
    nnz = rows.shape[0]
    d = h.shape[1]
    n_chunks = -(-nnz // chunk)
    if n_chunks <= 1:
        upd = vals[:, None] * h[cols]
        return jax.ops.segment_sum(upd, rows, num_segments=n_rows)

    pad = n_chunks * chunk - nnz
    rows_p = jnp.pad(rows, (0, pad), constant_values=n_rows)  # OOB -> dropped
    cols_p = jnp.pad(cols, (0, pad))
    vals_p = jnp.pad(vals, (0, pad))

    def body(acc, xs):
        r, c, v = xs
        upd = v[:, None] * h[c]
        return acc + jax.ops.segment_sum(upd, r, num_segments=n_rows), None

    acc0 = jnp.zeros((n_rows, d), h.dtype)
    acc, _ = jax.lax.scan(
        body, acc0,
        (rows_p.reshape(n_chunks, chunk), cols_p.reshape(n_chunks, chunk),
         vals_p.reshape(n_chunks, chunk)))
    return acc


def spdmm_exec(a: SparseCOO, h: jax.Array, chunk: int = 1_000_000) -> jax.Array:
    return coo_spdmm(a.rows, a.cols, a.vals, h, n_rows=a.shape[0], chunk=chunk)


def gemm_exec(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32)
