"""Static halo-exchange schedules for owned-operand sharding.

The replicated sharded executor (PR 8) ships the whole stripe-padded dense
operand ``Y`` to every device — O(N·width) memory per shard.  This module
implements the "own your band, exchange your halo" layout instead:

- **Ownership** partitions the ``ncb = ceil(K / B)`` block-rows of the
  stripe-padded operand contiguously across devices
  (:func:`ownership_starts`).  When the kernel is square on the adjacency
  (``M == K``) and row tiles are block-aligned, ownership follows the band
  placement itself, so a block-diagonal graph reads only blocks it already
  owns and exchanges NOTHING.
- **Column support** (:class:`ColumnSupport`) is what one device's band
  actually reads: its owned block-row range plus the sorted ``halo`` of
  foreign block-rows named by its SpDMM/SpMM descriptors.  A band with real
  GEMM tasks reads every block-row (``full=True``) — that device degrades to
  replicated-fallback accounting but the rest of the mesh still shrinks.
- **Schedule** (:func:`build_exchange`) compiles the supports into static
  per-device index arrays for a ring of ``nd - 1`` ``ppermute`` rounds: in
  round ``r`` device ``d`` holds the owned slab of device ``(d-1-r) % nd``
  and copies the blocks it needs into its local owned+halo buffer.  All
  shards run the identical program (shard_map requirement): take lists are
  padded to ``max_take`` with writes into a DUMP slot (local slot ``L``)
  that no descriptor ever reads for output rows.
- **Execution** (:func:`exchange`) runs inside the ``shard_map`` body,
  before the compute section, producing the ``(L + 1, B, W)`` local buffer
  whose slots the lowered descriptors index directly.

Bitwise identity with the replicated program holds by construction: the
exchange is pure data movement of the very rows ``_stripe_padded_y`` lays
out globally, descriptor entry ORDER is untouched (only the block-row
indices are remapped to local slots), so every output block sees the exact
same float contributions in the exact same order.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ColumnSupport:
    """Column support of ONE device's band over the dense operand.

    ``[own_start, own_stop)`` is the owned block-row range; ``halo`` the
    sorted foreign block-rows the band's descriptors read.  ``full=True``
    marks a band with real GEMM tasks — it reads every block-row, so its
    memory is accounted as replicated-fallback rather than owned+halo.
    """
    own_start: int
    own_stop: int
    halo: tuple[int, ...]
    full: bool = False

    @property
    def n_owned(self) -> int:
        return self.own_stop - self.own_start

    def local_blocks(self) -> list[int]:
        """Global block-rows resident in this device's local buffer, in
        local-slot order (sorted; owned and halo ranges are disjoint)."""
        return sorted(set(range(self.own_start, self.own_stop))
                      | set(self.halo))


@dataclasses.dataclass(frozen=True)
class HaloGeometry:
    """Static half of an exchange schedule (hashable → jit static arg).

    ``L`` is the local-buffer slot count excluding the dump slot (the
    buffer is ``(L + 1, B, W)`` with slot ``L`` absorbing padded writes);
    ``max_own``/``max_take`` equalize slab and take shapes across shards.
    ``n_rounds`` is ``nd - 1`` when anything is exchanged, else 0 — an
    empty-halo plan (block-diagonal graph) runs zero collective rounds.
    """
    n_devices: int
    ncb: int
    own_starts: tuple[int, ...]
    L: int
    max_own: int
    n_rounds: int
    max_take: int


def ownership_starts(M: int, K: int, tile_m: int, band_starts, block: int
                     ) -> tuple[int, ...]:
    """Contiguous ownership split of the ``ncb`` operand block-rows.

    Band-aligned when the kernel is square on the adjacency (``M == K``)
    and row tiles are block-aligned — then device ``d`` owns exactly the
    operand rows its own band produces, and block-diagonal structure makes
    every halo empty.  Otherwise an even contiguous split.
    """
    ncb = -(-K // block)
    nd = len(band_starts) - 1
    if M == K and tile_m % block == 0:
        bpt = tile_m // block
        starts = [min(int(bs) * bpt, ncb) for bs in band_starts]
        starts[-1] = ncb
    else:
        starts = [d * ncb // nd for d in range(nd)] + [ncb]
    return tuple(starts)


def build_exchange(supports, own_starts, *, gather: bool):
    """Compile column supports into a static ring-exchange schedule.

    Returns ``(HaloGeometry, own_dst, src, dst, gather_idx)`` numpy index
    arrays (leading device axis):

    - ``own_dst (nd, max_own)``: local slot of each owned block (pads → L);
    - ``src/dst (nd, n_rounds, max_take)``: per round, which slab slots to
      take from the transiting owned buffer and where to scatter them;
    - ``gather_idx (nd, ncb)`` (``gather=True`` only): local slot of every
      global block-row, for full-operand reconstruction on GEMM bands
      (slots of blocks a device never received stay at the dump slot — such
      devices only run PAD gemm tasks against all-zero X slabs).
    """
    nd = len(supports)
    ncb = int(own_starts[-1])
    locs = []
    for cs in supports:
        locs.append({g: i for i, g in enumerate(cs.local_blocks())})
    L = max((len(m) for m in locs), default=0)
    max_own = max(own_starts[d + 1] - own_starts[d] for d in range(nd))
    owner = np.searchsorted(own_starts, np.arange(ncb), side="right") - 1

    takes = [[[] for _ in range(max(nd - 1, 0))] for _ in range(nd)]
    for d, cs in enumerate(supports):
        for g in cs.halo:
            o = int(owner[g])
            r = (d - o - 1) % nd
            takes[d][r].append((g - int(own_starts[o]), locs[d][g]))
    max_take = max((len(t) for row in takes for t in row), default=0)
    n_rounds = nd - 1 if max_take else 0

    own_dst = np.full((nd, max_own), L, np.int32)
    for d in range(nd):
        for s in range(own_starts[d + 1] - own_starts[d]):
            own_dst[d, s] = locs[d][int(own_starts[d]) + s]

    src = np.zeros((nd, n_rounds, max_take), np.int32)
    dst = np.full((nd, n_rounds, max_take), L, np.int32)
    for d in range(nd):
        for r in range(n_rounds):
            for k, (s, t) in enumerate(takes[d][r]):
                src[d, r, k] = s
                dst[d, r, k] = t

    gather_idx = None
    if gather:
        gather_idx = np.full((nd, ncb), L, np.int32)
        for d in range(nd):
            for g, p in locs[d].items():
                gather_idx[d, g] = p

    hg = HaloGeometry(n_devices=nd, ncb=ncb, own_starts=tuple(own_starts),
                      L=L, max_own=max_own, n_rounds=n_rounds,
                      max_take=max_take)
    return hg, own_dst, src, dst, gather_idx


def exchange(local, y_own, hg: HaloGeometry):
    """Ring exchange INSIDE the shard_map body.

    ``local`` holds this shard's schedule arrays (``hx_own_dst``,
    ``hx_src``, ``hx_dst``); ``y_own (max_own, B, W)`` its owned slab of
    the stripe-padded operand.  Returns the ``(L + 1, B, W)`` owned+halo
    buffer.  ``n_rounds`` is static, so the ppermute chain unrolls at trace
    time — an empty-halo schedule emits NO collectives at all.
    """
    _, B, W = y_own.shape
    ybuf = jnp.zeros((hg.L + 1, B, W), y_own.dtype)
    ybuf = ybuf.at[local["hx_own_dst"]].set(y_own)
    transit = y_own
    perm = [(i, (i + 1) % hg.n_devices) for i in range(hg.n_devices)]
    for r in range(hg.n_rounds):
        transit = jax.lax.ppermute(transit, "data", perm=perm)
        ybuf = ybuf.at[local["hx_dst"][r]].set(transit[local["hx_src"][r]])
    return ybuf


def operand_bytes(supports, hg: HaloGeometry, block: int, width: int,
                  *, mode: str = "halo", bytes_per_elem: int = 4) -> dict:
    """Analytic per-device dense-operand memory of a sharded dispatch.

    ``width`` is the stripe-padded operand width (``nct * SN``).  The
    resident per-device footprint is uniform across shards (SPMD): the
    owned input slab plus the owned+halo buffer with its dump slot.  The
    replicated baseline is the full ``ncb`` block-rows on every device.
    """
    bb = block * width * bytes_per_elem
    per_device = []
    owned_b = halo_b = fallback_b = 0
    for cs in supports:
        o, h = cs.n_owned * bb, len(cs.halo) * bb
        if cs.full:
            per_device.append({"owned_bytes": o, "halo_bytes": 0,
                               "fallback_bytes": h, "full": True})
            fallback_b += h
        else:
            per_device.append({"owned_bytes": o, "halo_bytes": h,
                               "fallback_bytes": 0, "full": False})
            halo_b += h
        owned_b += o
    return {
        "mode": mode,
        "per_device": per_device,
        "owned_bytes": owned_b,
        "halo_bytes": halo_b,
        "fallback_bytes": fallback_b,
        "halo_per_device_bytes": (hg.max_own + hg.L + 1) * bb,
        "replicated_per_device_bytes": hg.ncb * bb,
    }
