"""Analyzer — Algorithm 4 lines 3-12.

For every task it evaluates the analytical performance model on both engines
and pushes the task into the Sparse Task Queue (STQ → ALU arrays / block-skip
kernels) or the Dense Task Queue (DTQ → AIE array / MXU GEMM).

Two strategies:

- ``greedy`` — the literal per-task rule of Alg. 4: compare t_ALU (ONE ALU
  array) against t_AIE and pick the faster engine.  (Note: the paper's
  listing line 9 reads ``if t_ALU > t_AIE then STQ.push`` which routes tasks
  to the engine the model says is slower; lines 10/11 are evidently
  transposed in typesetting — the surrounding text and every result table
  require the faster engine to win.  We implement the consistent rule.)

- ``balanced`` (default) — unit-aware list scheduling.  The platform has
  ``n_sparse_units`` ALU arrays but a single AIE array; a per-task comparison
  ignores queue contention (8 marginally-AIE-favored tasks would serialize on
  the AIE while 8 ALU arrays idle).  The paper's runtime achieves balance
  through its idle-unit pop loop (Alg. 4 lines 13-21) feeding from both
  queues it created; we model the combined analyzer+scheduler behaviour with
  a heterogeneous-makespan greedy (LPT): tasks in decreasing work order, each
  placed where its finish time is earliest.  LPT is a heuristic, not an
  optimum — on adversarial task sets the per-task greedy rule can beat it —
  so ``balanced`` simulates BOTH assignments with the Scheduler's own model
  (``scheduler.simulate``, which includes the memory-bandwidth bound) and
  returns whichever has the smaller modeled makespan (ties prefer LPT).
  The returned assignment is therefore never worse than ``greedy`` under
  the same :class:`HardwareModel` — measured (``CalibratedModel``) or
  analytical.  This reproduces the paper's reported hybrid wins (Tables
  VI/VII); ``greedy`` underuses the ALUs on medium-density kernels and is
  kept for ablation.

The ``hw`` argument is any :class:`HardwareModel`; engines whose model is an
uncalibrated ``fallback`` pass a measured ``CalibratedModel``
(repro.core.calibrate) here so the STQ/DTQ split follows device timings.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.partition import (
    DevicePlacement, KernelPartition, Task, band_partition)
from repro.core.perfmodel import HardwareModel, t_dense, t_sparse


def _fill_times(task: Task, hw: HardwareModel) -> None:
    task.t_dense = t_dense(task.shape, hw)
    ts, prim = t_sparse(task.shape, hw)
    task.t_sparse = ts
    task._sparse_prim = prim  # stash; queue decided by the strategy


def analyze_kernel(
    part: KernelPartition,
    hw: HardwareModel,
    strategy: str = "balanced",
) -> tuple[list[Task], list[Task]]:
    """Fill per-task primitive/queue decisions; return (STQ, DTQ)."""
    for task in part.tasks:
        _fill_times(task, hw)

    stq: list[Task] = []
    dtq: list[Task] = []

    if strategy == "greedy":
        for task in part.tasks:
            if task.t_sparse <= task.t_dense:
                task.primitive = task._sparse_prim
                task.queue = "STQ"
                stq.append(task)
            else:
                task.primitive = "GEMM"
                task.queue = "DTQ"
                dtq.append(task)
        return stq, dtq

    if strategy != "balanced":
        raise ValueError(strategy)

    # LPT over heterogeneous units: earliest-finish placement
    order = sorted(part.tasks, key=lambda t: -min(t.t_sparse, t.t_dense))
    sparse_free = [0.0] * hw.n_sparse_units
    heapq.heapify(sparse_free)
    dense_free = 0.0
    for task in order:
        s0 = sparse_free[0]
        finish_sparse = s0 + task.t_sparse
        finish_dense = dense_free + task.t_dense
        if finish_sparse <= finish_dense:
            heapq.heapreplace(sparse_free, finish_sparse)
            task.primitive = task._sparse_prim
            task.queue = "STQ"
            stq.append(task)
        else:
            dense_free = finish_dense
            task.primitive = "GEMM"
            task.queue = "DTQ"
            dtq.append(task)

    # LPT can lose to the per-task rule on adversarial sets (its ordering
    # ignores which engine a task prefers).  Simulate both assignments and
    # keep the better one, so "balanced ≤ greedy" holds by construction.
    from repro.core import scheduler as _scheduler
    lpt_makespan = _scheduler.simulate(stq, dtq, hw).makespan
    lpt_choice = [(t.queue, t.primitive) for t in part.tasks]
    g_stq, g_dtq = analyze_kernel(part, hw, "greedy")
    if _scheduler.simulate(g_stq, g_dtq, hw).makespan < lpt_makespan:
        return g_stq, g_dtq
    stq, dtq = [], []
    for task, (queue, prim) in zip(part.tasks, lpt_choice):
        task.queue, task.primitive = queue, prim
        (stq if queue == "STQ" else dtq).append(task)
    return stq, dtq


def analyze_sharded(
    part: KernelPartition,
    hws: list[HardwareModel],
    *,
    strategy: str = "balanced",
    mode: str = "dynamic",
) -> tuple[list[Task], list[Task], DevicePlacement]:
    """Two-level placement ``(device, queue)`` over a 1-D device mesh.

    Level 1: a min-makespan contiguous band partition of row-stripes over
    the per-device hardware models (:func:`band_partition`; the per-stripe
    cost on device ``d`` is the sum over the stripe's tasks of
    ``min(t_sparse, t_dense)`` under ``hws[d]`` — the best either engine of
    that device could do).  Level 2: the usual STQ/DTQ analysis is run
    independently inside each band, so a device's queue split follows ITS
    calibrated model.  Tasks get ``task.device`` filled; the concatenated
    (STQ, DTQ) queues plus the :class:`DevicePlacement` are returned.

    With one device this degenerates to :func:`analyze_kernel` /
    :func:`force_queue` on the full partition (band = all stripes).
    """
    n_dev = len(hws)
    if n_dev < 1:
        raise ValueError("analyze_sharded needs at least one hardware model")
    S = part.n_row_tiles
    loads = np.zeros((n_dev, S))
    for d, hw in enumerate(hws):
        for task in part.tasks:
            _fill_times(task, hw)
            loads[d, task.i] += min(task.t_sparse, task.t_dense)
    placement = DevicePlacement(n_dev, band_partition(loads, n_dev))

    stq: list[Task] = []
    dtq: list[Task] = []
    for d in range(n_dev):
        lo, hi = placement.band_starts[d], placement.band_starts[d + 1]
        band = [t for t in part.tasks if lo <= t.i < hi]
        for task in band:
            task.device = d
        if not band:
            continue
        sub = dataclasses.replace(part, tasks=band)
        if mode == "dynamic":
            s, q = analyze_kernel(sub, hws[d], strategy)
        elif mode == "sparse_only":
            s, q = force_queue(sub, hws[d], "STQ")
        elif mode == "dense_only":
            s, q = force_queue(sub, hws[d], "DTQ")
        else:
            raise ValueError(f"unknown mode {mode!r}")
        stq.extend(s)
        dtq.extend(q)
    return stq, dtq, placement


def force_queue(part: KernelPartition, hw: HardwareModel, queue: str) -> tuple[list[Task], list[Task]]:
    """Baselines: route EVERY task to one engine.

    ``queue="STQ"`` is the sparse-engine-only design; combined with dense
    feature accounting it reproduces the paper's "PL Only" baseline
    (Table VII — a BoostGCN-style PL design exploiting adjacency sparsity
    only); ``queue="DTQ"`` is the dense-only (AIE/GEMM-everything) baseline.
    """
    stq: list[Task] = []
    dtq: list[Task] = []
    for task in part.tasks:
        _fill_times(task, hw)
        if queue == "STQ":
            task.primitive = task._sparse_prim
            task.queue = "STQ"
            stq.append(task)
        else:
            task.primitive = "GEMM"
            task.queue = "DTQ"
            dtq.append(task)
    return stq, dtq
