"""Sharded AdamW with cosine schedule and global-norm clipping.

Moments live in the SAME sharding as the parameters (FSDP: optimizer state is
fully sharded — the classic ZeRO-3 layout), so the update is purely local;
gradient reduction happens inside the jitted step via GSPMD-inserted
reduce-scatters.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params: Any, moment_dtype=jnp.float32) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_update(grads: Any, opt_state: dict, params: Any,
                 cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mdt = mu.dtype
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu32 / b1c
        nhat = nu32 / b2c
        step_v = mhat / (jnp.sqrt(nhat) + cfg.eps)
        new_p = p - lr * (step_v + cfg.weight_decay * p)
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    out = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
