"""Int8 error-feedback gradient compression for cross-pod reduction.

At 1000+ nodes the data-parallel all-reduce crosses the DCN (pod) boundary
where bandwidth is ~10x scarcer than ICI.  We compress each gradient leaf to
int8 with a per-leaf scale before the cross-pod reduction and keep the
quantization residual as error-feedback state (Seide et al. / EF-SGD), which
restores convergence to the uncompressed trajectory.

Usage inside a jitted train step::

    grads, ef = compress_decompress(grads, ef)   # quantize-dequantize + EF
    # the all-reduce XLA inserts for the dp axis now moves int8-scale info
    # (with shard_map'd psum8 below it moves literal int8)

``psum8`` is the explicit shard_map collective variant: int8 payload +
float32 scale, summed per-axis, dequantized after.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads: Any, ef: Any) -> tuple[Any, Any]:
    """Quantize+dequantize each leaf with error feedback.

    Returns (decompressed grads to feed the optimizer, new EF state).  The
    EF state has the same pytree/sharding as the gradients.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq).astype(e.dtype)

    out = jax.tree.map(one, grads, ef)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_ef


def ef_init(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)


def psum8(x: jax.Array, axis_name: str) -> jax.Array:
    """Explicit int8 all-reduce for use inside ``shard_map``: the payload
    crossing the axis is int8 + one f32 scale (≈4x less DCN traffic than
    f32; int32 accumulation is exact up to 2^23 summands).

    All ranks must quantize against a SHARED scale, otherwise the integer
    sum mixes incompatible units — so a scalar pmax of the local maxima runs
    first (negligible traffic), then the int8 payload reduction."""
    smax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / smax), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return qsum.astype(jnp.float32) * smax
