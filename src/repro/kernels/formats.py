"""Block-sparse containers used by the TPU kernels.

The paper's PL datapath skips zero *elements* (COO scatter-gather).  On TPU the
natural skip unit is a tile: the MXU consumes 128x128 blocks and the VPU 8x128
lanes, so sub-tile skipping buys nothing.  ``BlockCSR`` stores only the nonzero
``B x B`` blocks of a matrix together with the scalar-prefetch metadata the
Pallas kernels consume (block-row ids, block-col ids, first-visit flags).

Packing happens on the host at *plan time* — the analogue of the paper's
preprocessing + APU runtime (Sections III-B and III-E).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def block_nonzero_mask(blocks, eps: float, *, axis, xp=np):
    """THE stored-block criterion, shared by every packer: a block is stored
    iff any element is nonzero (``eps == 0``) or any magnitude exceeds
    ``eps``.  ``axis`` selects the intra-block axes of ``blocks``; ``xp`` is
    the array namespace (``numpy`` for the host packers / capacity
    measurement, ``jax.numpy`` for the traceable device packer) so the
    host- and device-side packs can never disagree on what counts as
    stored."""
    if eps == 0.0:
        return xp.any(blocks != 0, axis=axis)
    return xp.any(xp.abs(blocks) > eps, axis=axis)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockCSR:
    """Block-compressed sparse row matrix.

    Blocks are stored sorted by (block_row, block_col).  Every block-row is
    guaranteed to contain at least one stored block (empty rows get a single
    zero block at column 0) so that Pallas output-block initialization via the
    ``first`` flag covers the whole output.  Stored blocks may be padded at the
    tail with zero blocks (``row_ids`` pointing at the last block-row,
    ``first = 0``) so repeated calls can share a compilation.
    """

    shape: Tuple[int, int]          # logical (M, K) — static
    block_size: int                 # B — static
    row_ids: jax.Array              # (nnzb,) int32 block-row of each block
    col_ids: jax.Array              # (nnzb,) int32 block-col of each block
    first: jax.Array                # (nnzb,) int32 1 iff first block in its row
    blocks: jax.Array               # (nnzb, B, B)
    nnzb: int                       # number of REAL (non-padding) blocks — static

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        leaves = (self.row_ids, self.col_ids, self.first, self.blocks)
        aux = (self.shape, self.block_size, self.nnzb)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        shape, block_size, nnzb = aux
        row_ids, col_ids, first, blocks = leaves
        return cls(shape, block_size, row_ids, col_ids, first, blocks, nnzb)

    # -- helpers -----------------------------------------------------------
    @property
    def n_block_rows(self) -> int:
        return _ceil_div(self.shape[0], self.block_size)

    @property
    def n_block_cols(self) -> int:
        return _ceil_div(self.shape[1], self.block_size)

    @property
    def stored_blocks(self) -> int:
        return int(self.blocks.shape[0])

    def block_density(self) -> float:
        return self.nnzb / max(1, self.n_block_rows * self.n_block_cols)

    def todense(self) -> jax.Array:
        """Dense reconstruction (host/oracle use)."""
        B = self.block_size
        M = self.n_block_rows * B
        K = self.n_block_cols * B
        dense = jnp.zeros((M, K), self.blocks.dtype)
        # scatter blocks (numpy loop is fine: oracle/host path only)
        rows = np.asarray(self.row_ids)
        cols = np.asarray(self.col_ids)
        blocks = np.asarray(self.blocks)
        out = np.zeros((M, K), dtype=blocks.dtype)
        for r, c, blk in zip(rows, cols, blocks):
            out[r * B:(r + 1) * B, c * B:(c + 1) * B] += blk
        dense = jnp.asarray(out)
        return dense[: self.shape[0], : self.shape[1]]


def pack_blockcsr(
    x: np.ndarray,
    block_size: int,
    *,
    capacity: int | None = None,
    dtype=None,
    eps: float = 0.0,
) -> BlockCSR:
    """Pack a dense host array into ``BlockCSR``, skipping all-zero blocks.

    ``capacity`` (optional) pads the stored-block count up so that different
    inputs with the same capacity reuse one compiled kernel.  Padding blocks
    point at the LAST block-row with ``first = 0`` — appended after the sorted
    real blocks they extend the final row's consecutive revisit run, which is
    required for output-buffer residency on real TPU grids.

    ``eps`` is the nonzero tolerance: blocks whose magnitudes are all
    ``<= eps`` are skipped (consistent with the Analyzer's density tolerance).
    """
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"BlockCSR expects a matrix, got shape {x.shape}")
    M, K = x.shape
    B = block_size
    nrb, ncb = _ceil_div(M, B), _ceil_div(K, B)
    padded = np.zeros((nrb * B, ncb * B), dtype=x.dtype)
    padded[:M, :K] = x

    # vectorized block scan (same reshape/lexsort approach as
    # ``pack_blockcsr_coo`` — no per-block Python loop): candidate blocks in
    # row-major order, empty block-rows refilled with a zero block at col 0
    xb = padded.reshape(nrb, B, ncb, B).transpose(0, 2, 1, 3)
    mask = block_nonzero_mask(xb, eps, axis=(2, 3))
    fill_rows = np.nonzero(~mask.any(axis=1))[0]
    r_real, c_real = np.nonzero(mask)          # row-major == (rb, cb) sorted
    rows_a = np.concatenate([r_real, fill_rows])
    cols_a = np.concatenate([c_real, np.zeros(len(fill_rows), np.int64)])
    blocks_a = np.concatenate(
        [xb[r_real, c_real], np.zeros((len(fill_rows), B, B), x.dtype)])
    order = np.lexsort((cols_a, rows_a))       # merge fillers into row order
    rows_a, cols_a, blocks_a = rows_a[order], cols_a[order], blocks_a[order]
    first_a = np.ones(len(rows_a), dtype=np.int32)
    first_a[1:] = (rows_a[1:] != rows_a[:-1]).astype(np.int32)

    nnzb = len(rows_a)
    cap = capacity if capacity is not None else nnzb
    if cap < nnzb:
        raise ValueError(f"capacity {cap} < stored blocks {nnzb}")
    pad = cap - nnzb
    if pad:
        rows_a = np.concatenate([rows_a, np.full(pad, nrb - 1, np.int64)])
        cols_a = np.concatenate([cols_a, np.zeros(pad, np.int64)])
        first_a = np.concatenate([first_a, np.zeros(pad, np.int32)])
        blocks_a = np.concatenate([blocks_a,
                                   np.zeros((pad, B, B), x.dtype)])

    out_dtype = dtype or x.dtype
    return BlockCSR(
        shape=(M, K),
        block_size=B,
        row_ids=jnp.asarray(rows_a, dtype=jnp.int32),
        col_ids=jnp.asarray(cols_a, dtype=jnp.int32),
        first=jnp.asarray(first_a, dtype=jnp.int32),
        blocks=jnp.asarray(blocks_a.astype(out_dtype)),
        nnzb=nnzb,
    )


def pack_blockcsr_coo(
    shape: Tuple[int, int],
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    block_size: int,
    *,
    capacity: int | None = None,
    dtype=None,
    eps: float = 0.0,
) -> BlockCSR:
    """Pack COO triplets into ``BlockCSR`` WITHOUT a dense intermediate.

    Bit-identical to ``pack_blockcsr(dense_of(triplets), ...)`` — duplicate
    coordinates are summed in triplet order (matching ``np.add.at`` on the
    densified matrix), blocks whose summed magnitudes are all ``<= eps`` are
    skipped, empty block-rows keep a zero block at column 0, and ``capacity``
    padding appends zero blocks on the last block-row — but the working set
    is O(nnz + stored_blocks · B²) instead of O(M · K).  This is what lets
    the engine pack a graph-scale adjacency's row-stripes at plan time.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    M, K = shape
    B = block_size
    nrb, ncb = _ceil_div(M, B), _ceil_div(K, B)
    if (np.any(rows >= M) or np.any(cols >= K)
            or np.any(rows < 0) or np.any(cols < 0)):
        raise ValueError("COO coordinate out of bounds for shape "
                         f"{(M, K)}")

    # candidate blocks = unique (block-row, block-col) pairs holding any nnz
    # (int64: block-grid sizes beyond 2^31 overflow the triplets' int32)
    key = rows.astype(np.int64) // B * ncb + cols // B
    uniq = np.unique(key)                       # sorted == (rb, cb) order
    blk_of = np.searchsorted(uniq, key)
    cand = np.zeros((len(uniq), B, B), dtype=vals.dtype)
    np.add.at(cand, (blk_of, rows % B, cols % B), vals)

    keep = block_nonzero_mask(cand, eps, axis=(1, 2))
    kept_keys = uniq[keep]
    kept_blocks = cand[keep]
    kept_rows = kept_keys // ncb
    kept_cols = kept_keys % ncb

    out_rows, out_cols, first, blocks = [], [], [], []
    ptr = 0
    zero_blk = np.zeros((B, B), dtype=vals.dtype)
    for rb in range(nrb):
        row_has_block = False
        while ptr < len(kept_keys) and kept_rows[ptr] == rb:
            out_rows.append(rb)
            out_cols.append(int(kept_cols[ptr]))
            first.append(0 if row_has_block else 1)
            blocks.append(kept_blocks[ptr])
            row_has_block = True
            ptr += 1
        if not row_has_block:  # keep output init coverage
            out_rows.append(rb)
            out_cols.append(0)
            first.append(1)
            blocks.append(zero_blk)

    nnzb = len(blocks)
    cap = capacity if capacity is not None else nnzb
    if cap < nnzb:
        raise ValueError(f"capacity {cap} < stored blocks {nnzb}")
    for _ in range(cap - nnzb):
        out_rows.append(nrb - 1)
        out_cols.append(0)
        first.append(0)
        blocks.append(zero_blk)

    out_dtype = dtype or vals.dtype
    return BlockCSR(
        shape=(M, K),
        block_size=B,
        row_ids=jnp.asarray(out_rows, dtype=jnp.int32),
        col_ids=jnp.asarray(out_cols, dtype=jnp.int32),
        first=jnp.asarray(first, dtype=jnp.int32),
        blocks=jnp.asarray(np.stack(blocks).astype(out_dtype)),
        nnzb=nnzb,
    )


def pair_block_triples(
    a: BlockCSR,
    y: BlockCSR,
    *,
    a_sentinel: int,
    y_sentinel: int,
    a_offset: int = 0,
    y_offset: int = 0,
    base_row: int = 0,
    base_col: int = 0,
    n_row_blocks: int | None = None,
    n_col_blocks: int | None = None,
) -> list[tuple[int, int, int, int]]:
    """Block-level Pairing Unit (Alg. 3 lines 3-5), region-relocatable.

    Intersects A's stored block-rows with Y's stored block-rows: each output
    block ``Z[jb, kb]`` receives one ``(a_id, y_id)`` pair per stored pair
    ``(A[jb, ib], Y[ib, kb])``, plus one ``(a_sentinel, y_sentinel)`` pair for
    every output block of the ``n_row_blocks x n_col_blocks`` region that
    receives no contribution (so Pallas initializes it).  Block ids are
    shifted by ``a_offset``/``y_offset`` (concatenated pools) and output
    coordinates by ``base_row``/``base_col`` (per-task regions of a fused
    launch).  Returns UNSORTED ``(out_row, out_col, a_id, y_id)`` quadruples
    in stored-block order; the caller sorts by output block and computes the
    first-visit flags.
    """
    a_rows = np.asarray(a.row_ids)[: a.stored_blocks]
    a_cols = np.asarray(a.col_ids)[: a.stored_blocks]
    y_rows = np.asarray(y.row_ids)[: y.stored_blocks]
    y_cols = np.asarray(y.col_ids)[: y.stored_blocks]
    n_row_blocks = a.n_block_rows if n_row_blocks is None else n_row_blocks
    n_col_blocks = y.n_block_cols if n_col_blocks is None else n_col_blocks

    # block-row index of Y: ib -> list of (y_block_id, kb)
    y_by_row: dict[int, list[tuple[int, int]]] = {}
    for yid, (ib, kb) in enumerate(zip(y_rows, y_cols)):
        y_by_row.setdefault(int(ib), []).append((yid, int(kb)))

    triples: list[tuple[int, int, int, int]] = []
    covered: set[tuple[int, int]] = set()
    for aid, (jb, ib) in enumerate(zip(a_rows, a_cols)):
        for yid, kb in y_by_row.get(int(ib), ()):
            triples.append((base_row + int(jb), base_col + kb,
                            a_offset + aid, y_offset + yid))
            covered.add((int(jb), kb))
    for jb in range(n_row_blocks):
        for kb in range(n_col_blocks):
            if (jb, kb) not in covered:
                triples.append((base_row + jb, base_col + kb,
                                a_sentinel, y_sentinel))
    return triples


def first_visit_flags(out_rows: np.ndarray, out_cols: np.ndarray) -> np.ndarray:
    """1 on the first entry of each (out_row, out_col) run (Pallas zero-init)."""
    first = np.zeros(len(out_rows), dtype=np.int32)
    seen: set[tuple[int, int]] = set()
    for i, (r, c) in enumerate(zip(out_rows, out_cols)):
        if (r, c) not in seen:
            first[i] = 1
            seen.add((r, c))
    return first


def spmm_triples(a: BlockCSR, y: BlockCSR) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Host-side pairing for a single-task SpMM.

    Returns arrays ``(a_ids, y_ids, out_rows, out_cols, first)`` sorted by
    output block, with one zero-pair appended for every output block that
    receives no contribution (so Pallas initializes it).  The zero pair
    indexes the sentinel block appended by the SpMM wrapper at position
    ``stored_blocks``.
    """
    if a.shape[1] != y.shape[0]:
        raise ValueError(f"spmm shape mismatch: {a.shape} x {y.shape}")
    if a.block_size != y.block_size:
        raise ValueError("spmm requires equal block sizes")

    triples = pair_block_triples(a, y, a_sentinel=a.stored_blocks,
                                 y_sentinel=y.stored_blocks)
    triples.sort()

    out_rows = np.array([t[0] for t in triples], dtype=np.int32)
    out_cols = np.array([t[1] for t in triples], dtype=np.int32)
    a_ids = np.array([t[2] for t in triples], dtype=np.int32)
    y_ids = np.array([t[3] for t in triples], dtype=np.int32)
    return a_ids, y_ids, out_rows, out_cols, first_visit_flags(out_rows, out_cols)
