"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.formats import BlockCSR


def gemm_ref(x: jax.Array, y: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def spdmm_ref(a: BlockCSR, y: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    dense = a.todense().astype(jnp.float32)
    k = y.shape[0]
    return jnp.dot(dense[:, :k], y.astype(jnp.float32)).astype(out_dtype)


def spmm_ref(a: BlockCSR, y: BlockCSR, out_dtype=jnp.float32) -> jax.Array:
    da = a.todense().astype(jnp.float32)
    dy = y.todense().astype(jnp.float32)
    return jnp.dot(da, dy).astype(out_dtype)
