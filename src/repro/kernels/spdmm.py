"""SpDMM Pallas kernel — block-sparse x dense (the PL ALU-array analogue).

Paper Alg. 2 pairs every nonzero element of X with q dense lanes of Y via the
Pairing Unit.  TPU-native version: the sparse operand is ``BlockCSR`` and the
grid iterates *only the stored blocks*; scalar-prefetched ``row_ids/col_ids``
arrays play the role of the Pairing Unit, steering each stored A-block to the
matching Y block-row and output block-row.  Work (and hence cycles) scales
with the number of stored blocks — i.e. with block density α_blk — exactly the
paper's ``α · mnd`` skip behaviour at tile granularity.

Grid order is ``(N/bn, nnzb)`` with the block index innermost: for a fixed
output column stripe, stored blocks are visited sorted by block-row, so output
block revisits are consecutive and the accumulator stays VMEM-resident
(TPU requirement); ``first`` flags zero-initialize each output row run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.formats import BlockCSR


def _spdmm_kernel(row_ref, col_ref, first_ref, a_ref, y_ref, z_ref):
    del col_ref
    b = pl.program_id(1)

    @pl.when(first_ref[b] == 1)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    # BlockSpec (None, B, B) squeezes the stored-block axis: a_ref is (B, B)
    z_ref[...] += jnp.dot(
        a_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(z_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret", "out_dtype"))
def spdmm(
    a: BlockCSR,
    y: jax.Array,
    *,
    bn: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``a @ y`` where ``a`` is BlockCSR and ``y`` dense ``(K, N)``.

    ``K`` and ``N`` must be multiples of ``a.block_size`` / ``bn``
    (the wrapper in ``ops.py`` pads).  Output is dense ``(M, N)`` where
    ``M = n_block_rows * block_size`` (caller slices).
    """
    B = a.block_size
    k, n = y.shape
    assert k == a.n_block_cols * B, (a.shape, y.shape, B)
    assert n % bn == 0, (n, bn)
    m_pad = a.n_block_rows * B
    nnzb = a.blocks.shape[0]

    grid = (n // bn, nnzb)
    return pl.pallas_call(
        _spdmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                # stored A blocks: one (B, B) block per inner step
                pl.BlockSpec((None, B, B), lambda j, b, rows, cols, first: (b, 0, 0)),
                # Y block-row selected by the block's column id (Pairing Unit)
                pl.BlockSpec((B, bn), lambda j, b, rows, cols, first: (cols[b], j)),
            ],
            out_specs=pl.BlockSpec(
                (B, bn), lambda j, b, rows, cols, first: (rows[b], j)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), out_dtype),
        interpret=interpret,
    )(a.row_ids, a.col_ids, a.first, a.blocks, y)


def _spdmm_fused_kernel(aid_ref, yrow_ref, orow_ref, ocol_ref, first_ref,
                        a_ref, y_ref, z_ref):
    del aid_ref, yrow_ref, orow_ref, ocol_ref
    t = pl.program_id(0)

    @pl.when(first_ref[t] == 1)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    z_ref[...] += jnp.dot(
        a_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(z_ref.dtype)


def _spdmm_fused_inplace_kernel(aid_ref, yrow_ref, orow_ref, ocol_ref,
                                first_ref, a_ref, y_ref, zin_ref, z_ref):
    del zin_ref
    _spdmm_fused_kernel(aid_ref, yrow_ref, orow_ref, ocol_ref, first_ref,
                        a_ref, y_ref, z_ref)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "bn", "m_pad", "interpret", "out_dtype",
                     "n_entries"),
)
def spdmm_fused(
    a_blocks: jax.Array,
    y: jax.Array,
    a_ids: jax.Array,
    y_rows: jax.Array,
    out_rows: jax.Array,
    out_cols: jax.Array,
    first: jax.Array,
    *,
    block_size: int,
    bn: int,
    m_pad: int,
    interpret: bool = False,
    out_dtype=jnp.float32,
    n_entries: int,
    z: jax.Array | None = None,
) -> jax.Array:
    """Fused multi-task SpDMM: EVERY SpDMM task of a kernel in one launch.

    ``a_blocks`` is the concatenated stored-block pool of all packed row
    stripes; ``y`` is dense, laid out with each col-stripe padded to ``bn``
    columns.  Each grid step ``t`` is one (stored block, task) pair: the
    scalar-prefetched entry arrays steer block ``a_ids[t]`` onto Y block-row
    ``y_rows[t]`` / col-stripe ``out_cols[t]`` and accumulate into output
    block ``(out_rows[t], out_cols[t])``.  Entries are sorted by output block
    so revisits are consecutive (VMEM residency); ``first`` zero-initializes
    each run.

    Without ``z``, the output is a fresh ``(m_pad, n_pad)`` buffer whose
    blocks covered by no entry are undefined (the caller must not read
    them).  With ``z`` — the scheduler's in-place assembly — the canvas is
    aliased to the output, so covered blocks are written in place and every
    other block keeps its ``z`` content (e.g. tiles already written by the
    batched GEMM of the same kernel).
    """
    B = block_size
    k_pad, n_pad = y.shape
    assert k_pad % B == 0 and n_pad % bn == 0, (y.shape, B, bn)

    in_specs = [
        pl.BlockSpec((None, B, B),
                     lambda t, aid, yrow, orow, ocol, first: (aid[t], 0, 0)),
        pl.BlockSpec((B, bn),
                     lambda t, aid, yrow, orow, ocol, first: (yrow[t], ocol[t])),
    ]
    operands = [a_ids, y_rows, out_rows, out_cols, first, a_blocks, y]
    kernel = _spdmm_fused_kernel
    out_shape = jax.ShapeDtypeStruct((m_pad, n_pad), out_dtype)
    aliases = {}
    if z is not None:
        assert z.shape == (m_pad, n_pad), (z.shape, m_pad, n_pad)
        # canvas input, aliased to the output buffer: the kernel never
        # reads it, so it stays in HBM (no per-step DMA)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        operands.append(z)
        kernel = _spdmm_fused_inplace_kernel
        out_shape = jax.ShapeDtypeStruct(z.shape, z.dtype)
        aliases = {7: 0}            # 5 scalar-prefetch + a + y -> z

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(n_entries,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (B, bn), lambda t, aid, yrow, orow, ocol, first: (orow[t], ocol[t])
            ),
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)
