"""Dense GEMM Pallas kernel — the MXU analogue of the paper's AIE array.

The AIE computation core streams row-major X / column-major Y partitions and
multiply-accumulates partial products across cycles (Fig. 3).  The TPU-native
equivalent is a three-level tiled matmul: grid ``(M/bm, N/bn, K/bk)`` with the
contraction dimension innermost so the output block stays resident in VMEM
while partial products accumulate (``@pl.when(k == 0)`` zero-init mirrors the
first-cycle load in Fig. 3).  Block shapes are MXU-aligned (multiples of 128 on
the minor dims) and sized so ``bm*bk + bk*bn + bm*bn`` floats fit VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, y_ref, z_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        z_ref[...] = acc_ref[...].astype(z_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def gemm(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """``x @ y`` with explicit MXU tiling.  Shapes must be block-divisible
    (the public wrapper in ``ops.py`` pads)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, y.shape, bm, bn, bk)
    out_dtype = out_dtype or x.dtype
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def _gemm_batch_scatter_kernel(row_ref, col_ref, x_ref, y_ref, zin_ref, z_ref,
                               acc_ref, *, n_k: int):
    del row_ref, col_ref, zin_ref
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], y_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        z_ref[...] = acc_ref[...].astype(z_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bk", "interpret")
)
def gemm_batch_scatter(
    x: jax.Array,
    y: jax.Array,
    rows: jax.Array,
    cols: jax.Array,
    z: jax.Array,
    *,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Batched tile GEMM with an in-place scatter output map.

    Like :func:`gemm_batch`, but instead of returning a ``(T, m, n)`` stack
    the output index map places task ``t``'s tile directly at tile
    coordinates ``(rows[t], cols[t])`` of the caller's canvas ``z`` — the
    final padded ``(M, N)`` layout of the plan's partition.  ``z`` is aliased
    to the output, so tiles owned by other primitives (or by no task) keep
    whatever ``z`` already holds; the scheduler's assembly is one slice
    instead of a per-task ``.at[].set`` loop.  ``z`` dims must be multiples
    of the tile dims ``(m, n)``.
    """
    t, m, k = x.shape
    t2, k2, n = y.shape
    assert t == t2 and k == k2, (x.shape, y.shape)
    assert k % bk == 0, (k, bk)
    mz, nz = z.shape
    assert mz % m == 0 and nz % n == 0, (z.shape, (m, n))
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_gemm_batch_scatter_kernel, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(t, n_k),
            in_specs=[
                pl.BlockSpec((1, m, bk), lambda i, kk, rows, cols: (i, 0, kk)),
                pl.BlockSpec((1, bk, n), lambda i, kk, rows, cols: (i, kk, 0)),
                # canvas input, aliased to the output buffer: the kernel
                # never reads it, so it stays in HBM (no per-step DMA)
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(
                (m, n), lambda i, kk, rows, cols: (rows[i], cols[i])
            ),
            scratch_shapes=[pltpu.VMEM((m, n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        input_output_aliases={4: 0},    # 2 scalar-prefetch + x + y -> z
        interpret=interpret,
    )(rows, cols, x, y, z)


def _gemm_batch_kernel(x_ref, y_ref, z_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], y_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        z_ref[0] = acc_ref[...].astype(z_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bk", "interpret", "out_dtype")
)
def gemm_batch(
    x: jax.Array,
    y: jax.Array,
    *,
    bk: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Batched tile GEMM: ``z[t] = x[t] @ y[t]`` in ONE pallas_call.

    ``x`` is ``(T, m, k)`` (the stacked DTQ row-stripes), ``y`` is
    ``(T, k, n)`` (the matching col-stripes).  The grid is ``(T, k/bk)`` with
    the contraction innermost, so each task's output tile stays VMEM-resident
    while its partial products accumulate — the whole Dense Task Queue drains
    with a single kernel launch instead of one launch per task.
    """
    t, m, k = x.shape
    t2, k2, n = y.shape
    assert t == t2 and k == k2, (x.shape, y.shape)
    assert k % bk == 0, (k, bk)
    out_dtype = out_dtype or x.dtype
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_gemm_batch_kernel, n_k=n_k),
        grid=(t, n_k),
        in_specs=[
            pl.BlockSpec((1, m, bk), lambda i, kk: (i, 0, kk)),
            pl.BlockSpec((1, bk, n), lambda i, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i, kk: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, n), jnp.float32)],
        interpret=interpret,
    )(x, y)
