"""SpMM Pallas kernel — block-sparse x block-sparse (paper Alg. 3).

Row-wise product: ``Z[jb] = Σ_ib A[jb, ib] · Y[ib]`` computed only over pairs
where BOTH blocks are stored.  The host-side ``spmm_triples`` pairing (the
paper's Pairing Unit intersecting X's row nonzeros with Y's stored rows)
produces a flat triple list sorted by output block; the grid walks that list,
so compute scales with ``α_blk(A) · α_blk(Y)`` — the paper's
``α_X · α_Y · mnd`` term at tile granularity.

Each grid step multiplies one stored-A block into one stored-Y block and
accumulates into the output block addressed by the scalar-prefetched
``out_rows/out_cols``; sorting makes revisits consecutive (VMEM residency) and
``first`` flags zero-initialize.  A sentinel zero block appended after the
stored blocks backs the padding triples that cover otherwise-empty output
blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.formats import BlockCSR, spmm_triples


def _spmm_kernel(aid_ref, yid_ref, orow_ref, ocol_ref, first_ref,
                 a_ref, y_ref, z_ref):
    del aid_ref, yid_ref, orow_ref, ocol_ref
    t = pl.program_id(0)

    @pl.when(first_ref[t] == 1)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    # BlockSpec (None, B, B) squeezes the stored-block axis: refs are (B, B)
    z_ref[...] += jnp.dot(
        a_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(z_ref.dtype)


def _spmm_inplace_kernel(aid_ref, yid_ref, orow_ref, ocol_ref, first_ref,
                         a_ref, y_ref, zin_ref, z_ref):
    del zin_ref
    _spmm_kernel(aid_ref, yid_ref, orow_ref, ocol_ref, first_ref,
                 a_ref, y_ref, z_ref)


@functools.partial(
    jax.jit,
    static_argnames=("m_pad", "n_pad", "block_size", "interpret", "out_dtype",
                     "n_triples"),
)
def _spmm_call(a_blocks, y_blocks, a_ids, y_ids, out_rows, out_cols, first,
               *, m_pad, n_pad, block_size, interpret, out_dtype, n_triples,
               z=None):
    B = block_size
    in_specs = [
        pl.BlockSpec((None, B, B), lambda t, aid, yid, orow, ocol, first: (aid[t], 0, 0)),
        pl.BlockSpec((None, B, B), lambda t, aid, yid, orow, ocol, first: (yid[t], 0, 0)),
    ]
    operands = [a_ids, y_ids, out_rows, out_cols, first, a_blocks, y_blocks]
    kernel = _spmm_kernel
    out_shape = jax.ShapeDtypeStruct((m_pad, n_pad), out_dtype)
    aliases = {}
    if z is not None:
        assert z.shape == (m_pad, n_pad), (z.shape, m_pad, n_pad)
        # canvas input, aliased to the output buffer: the kernel never
        # reads it, so it stays in HBM (no per-step DMA)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        operands.append(z)
        kernel = _spmm_inplace_kernel
        out_shape = jax.ShapeDtypeStruct(z.shape, z.dtype)
        aliases = {7: 0}            # 5 scalar-prefetch + a + y -> z

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(n_triples,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (B, B), lambda t, aid, yid, orow, ocol, first: (orow[t], ocol[t])
            ),
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)


def spmm(
    a: BlockCSR,
    y: BlockCSR,
    *,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``a @ y`` with both operands BlockCSR.  Returns dense
    ``(n_block_rows(a)*B, n_block_cols(y)*B)`` (caller slices to logical)."""
    B = a.block_size
    a_ids, y_ids, out_rows, out_cols, first = spmm_triples(a, y)

    # sentinel zero blocks backing the padding triples
    zero = jnp.zeros((1, B, B), a.blocks.dtype)
    a_blocks = jnp.concatenate([a.blocks, zero], axis=0)
    zero_y = jnp.zeros((1, B, B), y.blocks.dtype)
    y_blocks = jnp.concatenate([y.blocks, zero_y], axis=0)

    return _spmm_call(
        a_blocks, y_blocks,
        jnp.asarray(a_ids), jnp.asarray(y_ids),
        jnp.asarray(out_rows), jnp.asarray(out_cols), jnp.asarray(first),
        m_pad=a.n_block_rows * B,
        n_pad=y.n_block_cols * B,
        block_size=B,
        interpret=interpret,
        out_dtype=out_dtype,
        n_triples=len(a_ids),
    )


def spmm_fused(
    a_blocks: jax.Array,
    y_blocks: jax.Array,
    a_ids,
    y_ids,
    out_rows,
    out_cols,
    first,
    *,
    block_size: int,
    m_pad: int,
    n_pad: int,
    interpret: bool = False,
    out_dtype=jnp.float32,
    z: jax.Array | None = None,
) -> jax.Array:
    """Fused multi-task SpMM: a caller-built triple list over CONCATENATED
    block pools (all packed A row-stripes / Y col-stripes of a kernel, plus
    one trailing sentinel zero block each) drives a single launch of the
    triple-walking kernel.  The caller offsets block ids into the pools and
    output coordinates into per-task regions; sorting/coverage obligations are
    the same as :func:`repro.kernels.formats.spmm_triples`.

    ``z`` (optional) is an in-place canvas aliased to the output: triples
    scatter into it and every block they don't cover keeps its ``z`` content
    (the scheduler's O(1) assembly)."""
    return _spmm_call(
        jnp.asarray(a_blocks), jnp.asarray(y_blocks),
        jnp.asarray(a_ids, dtype=jnp.int32), jnp.asarray(y_ids, dtype=jnp.int32),
        jnp.asarray(out_rows, dtype=jnp.int32),
        jnp.asarray(out_cols, dtype=jnp.int32),
        jnp.asarray(first, dtype=jnp.int32),
        m_pad=m_pad,
        n_pad=n_pad,
        block_size=block_size,
        interpret=interpret,
        out_dtype=out_dtype,
        n_triples=len(a_ids),
        z=z,
    )
