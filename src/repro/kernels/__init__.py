"""Pallas TPU kernels for the paper's compute primitives.

- ``gemm``  — dense matmul on the MXU (AIE-array analogue)
- ``spdmm`` — block-sparse x dense (PL ALU-array SpDMM analogue)
- ``spmm``  — block-sparse x block-sparse (PL ALU-array SpMM analogue)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py``.  Written for TPU (BlockSpec VMEM tiling, scalar prefetch), they are
validated on CPU in ``interpret=True`` mode.
"""
from repro.kernels.formats import BlockCSR, pack_blockcsr, spmm_triples
from repro.kernels.ops import gemm, spdmm, spmm, default_interpret

__all__ = [
    "BlockCSR", "pack_blockcsr", "spmm_triples",
    "gemm", "spdmm", "spmm", "default_interpret",
]
