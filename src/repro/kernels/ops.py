"""Public jit'd wrappers around the Pallas kernels.

Handles shape padding to block multiples, dtype policy (f32 accumulation) and
the interpret-mode fallback (this container is CPU-only; the kernels target
TPU, and ``interpret=True`` executes the kernel body on CPU for validation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gemm as _gemm
from repro.kernels import spdmm as _spdmm
from repro.kernels import spmm as _spmm
from repro.kernels.formats import (BlockCSR, block_nonzero_mask,
                                   pack_blockcsr)


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# Pallas-invocation accounting: every public wrapper bumps this counter, so
# the scheduler tests/benchmarks can assert batched dispatch really issues
# O(primitives) launches per kernel instead of O(tasks).
_PALLAS_CALLS = 0


def _count_call() -> None:
    global _PALLAS_CALLS
    _PALLAS_CALLS += 1


def pallas_call_count() -> int:
    return _PALLAS_CALLS


def reset_pallas_call_count() -> None:
    global _PALLAS_CALLS
    _PALLAS_CALLS = 0


def _pad_to(x: jax.Array, m: int, n: int) -> jax.Array:
    pm = m - x.shape[0]
    pn = n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _round_up(x: int, b: int) -> int:
    return -(-x // b) * b


def gemm(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128,
         interpret: bool | None = None, out_dtype=None):
    """Dense ``x @ y`` via the MXU-tiled Pallas kernel (pads + slices)."""
    interpret = default_interpret() if interpret is None else interpret
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm_, bn_, bk_ = (min(bm, _round_up(m, 8)), min(bn, _round_up(n, 8)),
                     min(bk, _round_up(k, 8)))
    mp, np_, kp = _round_up(m, bm_), _round_up(n, bn_), _round_up(k, bk_)
    _count_call()
    out = _gemm.gemm(_pad_to(x, mp, kp), _pad_to(y, kp, np_),
                     bm=bm_, bn=bn_, bk=bk_, interpret=interpret,
                     out_dtype=out_dtype)
    return out[:m, :n]


def gemm_batch(x, y, *, bk: int = 128, interpret: bool | None = None,
               out_dtype=jnp.float32):
    """Batched tile GEMM ``z[t] = x[t] @ y[t]`` in one pallas_call.

    ``x`` is ``(T, m, k)``, ``y`` is ``(T, k, n)``; tile dims are padded to
    lane multiples and the output sliced back to ``(T, m, n)``."""
    interpret = default_interpret() if interpret is None else interpret
    t, m, k = x.shape
    t2, k2, n = y.shape
    assert t == t2 and k == k2, (x.shape, y.shape)
    bk_ = min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, 8), _round_up(n, 8), _round_up(k, bk_)
    x = jnp.pad(x, ((0, 0), (0, mp - m), (0, kp - k)))
    y = jnp.pad(y, ((0, 0), (0, kp - k), (0, np_ - n)))
    _count_call()
    out = _gemm.gemm_batch(x, y, bk=bk_, interpret=interpret,
                           out_dtype=out_dtype)
    return out[:, :m, :n]


def gemm_batch_scatter(x, y, rows, cols, z, *, bk: int = 128,
                       interpret: bool | None = None):
    """Batched tile GEMM scattered in place: ``z`` at tile coords
    ``(rows[t], cols[t])`` receives ``x[t] @ y[t]`` — one pallas_call, no
    host-side reassembly.  ``x`` is ``(T, m, k)``, ``y`` is ``(T, k, n)``
    and ``z``'s dims must be multiples of ``(m, n)`` (the scheduler's padded
    canvas guarantees this); tiles of ``z`` no task addresses keep their
    content (aliased output)."""
    interpret = default_interpret() if interpret is None else interpret
    t, m, k = x.shape
    t2, k2, n = y.shape
    assert t == t2 and k == k2, (x.shape, y.shape)
    bk_ = min(bk, _round_up(k, 8))
    kp = _round_up(k, bk_)
    x = jnp.pad(x, ((0, 0), (0, 0), (0, kp - k)))
    y = jnp.pad(y, ((0, 0), (0, kp - k), (0, 0)))
    _count_call()
    return _gemm.gemm_batch_scatter(
        x, y, jnp.asarray(rows, dtype=jnp.int32),
        jnp.asarray(cols, dtype=jnp.int32), z, bk=bk_, interpret=interpret)


def spdmm(a: BlockCSR, y, *, bn: int = 128, interpret: bool | None = None,
          out_dtype=jnp.float32):
    """Block-sparse ``a @ y`` (pads Y, slices output to logical shape)."""
    interpret = default_interpret() if interpret is None else interpret
    m, k = a.shape
    k2, n = y.shape
    assert k == k2, (a.shape, y.shape)
    bn_ = min(bn, _round_up(n, 8))
    kp = a.n_block_cols * a.block_size
    np_ = _round_up(n, bn_)
    _count_call()
    out = _spdmm.spdmm(a, _pad_to(y, kp, np_), bn=bn_, interpret=interpret,
                       out_dtype=out_dtype)
    return out[:m, :n]


def spdmm_fused(a_blocks, y, a_ids, y_rows, out_rows, out_cols, first, *,
                block_size: int, bn: int, m_pad: int,
                interpret: bool | None = None, out_dtype=jnp.float32,
                z=None):
    """Fused multi-task SpDMM over a concatenated stored-block pool; see
    :func:`repro.kernels.spdmm.spdmm_fused`.  ``y`` must already be laid out
    with ``bn``-padded col-stripes.  ``z`` (optional) is an in-place canvas
    aliased to the output: uncovered blocks keep their ``z`` content."""
    interpret = default_interpret() if interpret is None else interpret
    _count_call()
    return _spdmm.spdmm_fused(
        jnp.asarray(a_blocks), jnp.asarray(y),
        jnp.asarray(a_ids, dtype=jnp.int32),
        jnp.asarray(y_rows, dtype=jnp.int32),
        jnp.asarray(out_rows, dtype=jnp.int32),
        jnp.asarray(out_cols, dtype=jnp.int32),
        jnp.asarray(first, dtype=jnp.int32),
        block_size=block_size, bn=bn, m_pad=m_pad, interpret=interpret,
        out_dtype=out_dtype, n_entries=len(a_ids), z=z)


def blockize(y, block: int):
    """Dense ``(R*B, C*B)`` matrix → ``(R*C, B, B)`` block pool in row-major
    block order (``pool[r*C + c] == y[r*B:(r+1)*B, c*B:(c+1)*B]``).

    The compiled-dispatch SpMM path derives its Y operand pool from the dense
    matrix at run time (a reshape/transpose, no host packing), addressed by
    plan-time ``y_id = row_block * C + col_block`` descriptors."""
    m, n = y.shape
    assert m % block == 0 and n % block == 0, (y.shape, block)
    r, c = m // block, n // block
    return y.reshape(r, block, c, block).transpose(0, 2, 1, 3).reshape(
        r * c, block, block)


def pack_activation_stripes(x, *, block: int, n_stripes: int, slot_rows: int,
                            n_block_cols: int, capacity,
                            eps: float = 0.0):
    """Traceable capacity-padded BlockCSR packing of a dense activation.

    The device-resident analogue of per-row-stripe :func:`pack_blockcsr` —
    runs INSIDE a jitted program (no host round-trip), with **fixed shapes**
    so one trace serves any activation sparsity within the stored-block
    budget.  ``x`` is the dense ``(M, K)`` operand; ``capacity`` is either a
    static int (every stripe gets the same budget) or a static per-stripe
    vector of ``n_stripes`` ints (skew-aware budgets — stripes packed back
    to back at flat offsets ``cumsum(capacity)``, so the trace shape depends
    only on the TOTAL slot count).  Each of the ``n_stripes`` canvas
    row-stripes (``slot_rows`` block-rows tall) is packed into exactly its
    budgeted number of block slots:

    - stored blocks (any ``|elem| > eps``; ``!= 0`` when ``eps == 0``) fill
      slots in row-major (block-row, block-col) order — the same order
      ``pack_blockcsr`` emits;
    - block-rows with no stored block keep one zero block at column 0 with
      ``first = 1`` (output-init coverage), including the canvas padding
      rows past the logical extent;
    - remaining slots are the capacity-padding convention: zero block at
      the LAST block-row, column 0, ``first = 0`` — exact bitwise no-ops.

    Returns ``(blocks, row_ids, col_ids, first, nnzb, real, overflow)``:
    the pooled ``(sum(capacity), B, B)`` slot payloads, the flat per-slot
    metadata (int32, indexable by ``offset[stripe] + slot`` — with a scalar
    capacity that is the familiar ``stripe * capacity + slot``), the
    per-stripe SLOT counts (stored blocks + empty-row fillers — what the
    budget must cover), the per-stripe count of REAL stored blocks (fillers
    excluded — the honest skip telemetry), and a scalar bool that is True
    when ANY stripe needs more than its budgeted slots (blocks past the
    budget are dropped — the caller must take its dense fallback).
    """
    B, S, R, C = block, n_stripes, slot_rows, n_block_cols
    caps = np.asarray(capacity, dtype=np.int64)
    if caps.ndim == 0:
        caps = np.full(S, int(caps), dtype=np.int64)
    assert caps.shape == (S,), (caps.shape, S)
    offs = np.concatenate([np.zeros(1, np.int64), np.cumsum(caps)])
    total = int(offs[-1])
    x = jnp.asarray(x)
    M, K = x.shape
    xp = jnp.pad(x, ((0, S * R * B - M), (0, C * B - K)))
    xb = xp.reshape(S, R, B, C, B).transpose(0, 1, 3, 2, 4)   # (S,R,C,B,B)
    mask = block_nonzero_mask(xb, eps, axis=(-2, -1), xp=jnp)
    row_has = jnp.any(mask, axis=2)                           # (S, R)
    col0 = jax.lax.broadcasted_iota(jnp.int32, (S, R, C), 2) == 0
    stored = mask | ((~row_has)[:, :, None] & col0)
    first = stored & (jnp.cumsum(stored.astype(jnp.int32), axis=2) == 1)

    flat = stored.reshape(S, R * C)
    cnt = jnp.cumsum(flat.astype(jnp.int32), axis=1)
    slot = cnt - 1
    nnzb = cnt[:, -1]
    # filler/padding slots carry EXACT zero blocks (jnp.where, not a mask
    # multiply: ``-x * 0 == -0.0`` would leak signed zeros into the pool)
    blocks = jnp.where(mask[..., None, None], xb,
                       jnp.zeros((), x.dtype)).reshape(S, R * C, B, B)
    r_idx = jax.lax.broadcasted_iota(jnp.int32, (S, R, C), 1).reshape(S, R * C)
    c_idx = jax.lax.broadcasted_iota(jnp.int32, (S, R, C), 2).reshape(S, R * C)
    # scatter each stored block to its flat slot ``offset[stripe] + slot``;
    # non-stored and over-budget blocks target slot == total, which 'drop'
    # discards.  With a scalar capacity the offsets are ``stripe * cap`` and
    # the layout is bit-identical to the historical 2-D (S, cap) scatter.
    caps_j = jnp.asarray(caps, jnp.int32)[:, None]        # (S, 1), static
    offs_j = jnp.asarray(offs[:-1], jnp.int32)[:, None]
    tgt = jnp.where(flat & (slot < caps_j), offs_j + slot, total).reshape(-1)
    pool = jnp.zeros((total, B, B), x.dtype
                     ).at[tgt].set(blocks.reshape(S * R * C, B, B),
                                   mode="drop")
    row_ids = jnp.full((total,), R - 1, jnp.int32
                       ).at[tgt].set(r_idx.reshape(-1), mode="drop")
    col_ids = jnp.zeros((total,), jnp.int32
                        ).at[tgt].set(c_idx.reshape(-1), mode="drop")
    first_f = jnp.zeros((total,), jnp.int32).at[tgt].set(
        first.reshape(-1).astype(jnp.int32), mode="drop")
    return (pool, row_ids, col_ids, first_f, nnzb,
            jnp.sum(mask.astype(jnp.int32), axis=(1, 2)),
            jnp.any(nnzb > jnp.asarray(caps, jnp.int32)))


def spmm(a: BlockCSR, y: BlockCSR, *, interpret: bool | None = None,
         out_dtype=jnp.float32):
    """Block-sparse ``a @ y`` with both operands sparse."""
    interpret = default_interpret() if interpret is None else interpret
    m, _ = a.shape
    _, n = y.shape
    _count_call()
    out = _spmm.spmm(a, y, interpret=interpret, out_dtype=out_dtype)
    return out[:m, :n]


def spmm_fused(a_blocks, y_blocks, a_ids, y_ids, out_rows, out_cols, first, *,
               block_size: int, m_pad: int, n_pad: int,
               interpret: bool | None = None, out_dtype=jnp.float32, z=None):
    """Fused multi-task SpMM over concatenated block pools; see
    :func:`repro.kernels.spmm.spmm_fused`.  ``z`` (optional) is an in-place
    canvas aliased to the output: uncovered blocks keep their ``z`` content."""
    interpret = default_interpret() if interpret is None else interpret
    _count_call()
    return _spmm.spmm_fused(
        a_blocks, y_blocks, a_ids, y_ids, out_rows, out_cols, first,
        block_size=block_size, m_pad=m_pad, n_pad=n_pad, interpret=interpret,
        out_dtype=out_dtype, z=z)


__all__ = [
    "BlockCSR", "pack_blockcsr", "pack_activation_stripes", "blockize",
    "gemm", "gemm_batch", "gemm_batch_scatter",
    "spdmm", "spdmm_fused", "spmm", "spmm_fused", "default_interpret",
    "pallas_call_count", "reset_pallas_call_count",
]
