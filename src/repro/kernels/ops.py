"""Public jit'd wrappers around the Pallas kernels.

Handles shape padding to block multiples, dtype policy (f32 accumulation) and
the interpret-mode fallback (this container is CPU-only; the kernels target
TPU, and ``interpret=True`` executes the kernel body on CPU for validation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gemm as _gemm
from repro.kernels import spdmm as _spdmm
from repro.kernels import spmm as _spmm
from repro.kernels.formats import BlockCSR, pack_blockcsr


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, m: int, n: int) -> jax.Array:
    pm = m - x.shape[0]
    pn = n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _round_up(x: int, b: int) -> int:
    return -(-x // b) * b


def gemm(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128,
         interpret: bool | None = None, out_dtype=None):
    """Dense ``x @ y`` via the MXU-tiled Pallas kernel (pads + slices)."""
    interpret = default_interpret() if interpret is None else interpret
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm_, bn_, bk_ = (min(bm, _round_up(m, 8)), min(bn, _round_up(n, 8)),
                     min(bk, _round_up(k, 8)))
    mp, np_, kp = _round_up(m, bm_), _round_up(n, bn_), _round_up(k, bk_)
    out = _gemm.gemm(_pad_to(x, mp, kp), _pad_to(y, kp, np_),
                     bm=bm_, bn=bn_, bk=bk_, interpret=interpret,
                     out_dtype=out_dtype)
    return out[:m, :n]


def spdmm(a: BlockCSR, y, *, bn: int = 128, interpret: bool | None = None,
          out_dtype=jnp.float32):
    """Block-sparse ``a @ y`` (pads Y, slices output to logical shape)."""
    interpret = default_interpret() if interpret is None else interpret
    m, k = a.shape
    k2, n = y.shape
    assert k == k2, (a.shape, y.shape)
    bn_ = min(bn, _round_up(n, 8))
    kp = a.n_block_cols * a.block_size
    np_ = _round_up(n, bn_)
    out = _spdmm.spdmm(a, _pad_to(y, kp, np_), bn=bn_, interpret=interpret,
                       out_dtype=out_dtype)
    return out[:m, :n]


def spmm(a: BlockCSR, y: BlockCSR, *, interpret: bool | None = None,
         out_dtype=jnp.float32):
    """Block-sparse ``a @ y`` with both operands sparse."""
    interpret = default_interpret() if interpret is None else interpret
    m, _ = a.shape
    _, n = y.shape
    out = _spmm.spmm(a, y, interpret=interpret, out_dtype=out_dtype)
    return out[:m, :n]


__all__ = [
    "BlockCSR", "pack_blockcsr", "gemm", "spdmm", "spmm", "default_interpret",
]
