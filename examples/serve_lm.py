"""Batched serving demo: greedy decode with KV cache on reduced configs,
including the MoE arch whose expert dispatch routes through the paper's
analyzer.

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

for arch in ("qwen2.5-3b", "deepseek-v2-lite-16b", "mamba2-780m"):
    print(f"== {arch} ==")
    subprocess.run([sys.executable, "-m", "repro.launch.serve",
                    "--arch", arch, "--batch", "2", "--prompt-len", "8",
                    "--gen", "8"], check=True)
