"""Full-graph GNN inference across models x datasets (paper Table VI shape).

    PYTHONPATH=src python examples/gnn_inference.py [--datasets CO,CI,PU]
"""
import argparse

from repro.core import DynasparseEngine
from repro.data.graphs import load_graph
from repro.models import gnn

ap = argparse.ArgumentParser()
ap.add_argument("--datasets", default="CO,CI,PU")
ap.add_argument("--models", default="GCN,GraphSAGE,GIN,SGC")
args = ap.parse_args()

print(f"{'model':>10} {'ds':>3} {'hw time (ms)':>12} {'dense/executed FLOPs':>21}")
for model in args.models.split(","):
    for ds in args.datasets.split(","):
        g = load_graph(ds)
        h = g.features
        params = gnn.init_params(model, h.shape[1], g.stats.hidden,
                                 g.stats.classes)
        eng = DynasparseEngine()
        _, report = gnn.run_inference(model, eng, g.adj, h, params)
        tot = report.total
        print(f"{model:>10} {ds:>3} {report.hardware_time * 1e3:>12.4f} "
              f"{tot.flops_dense_equiv / tot.flops_executed:>20.1f}x")
