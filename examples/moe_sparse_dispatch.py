"""The paper's technique inside the LM stack: MoE expert dispatch as
block-sparse matmul.

Top-6-of-64 routing means the token->expert activation matrix has 9.4%
density; the analyzer (TPU-v5e perf model) picks the sparse dispatch path,
and the block-sparse SpDMM kernel computes the same result as a dense
masked GEMM — demonstrated numerically here.

    PYTHONPATH=src python examples/moe_sparse_dispatch.py
"""
import numpy as np
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.reduced import reduce_config
from repro.models.ffn import moe_dispatch_report
from repro.kernels import ops
from repro.kernels.formats import pack_blockcsr

cfg = reduce_config(ARCHS["deepseek-v2-lite-16b"])
rep = moe_dispatch_report(ARCHS["deepseek-v2-lite-16b"], tokens=4096)
print("analyzer decision for deepseek-v2-lite dispatch "
      f"(density {rep['density']:.3f}): {rep['primitive']}")
print(f"  t_dense={rep['t_dense']:.3e}s  t_sparse={rep['t_sparse']:.3e}s")

# numeric demo: block-sparse expert activation x dense weight
rng = np.random.default_rng(0)
T, E, B = 64, 8, 8          # tokens, experts, block
mask = np.zeros((T // B, E), np.float32)
for i in range(T // B):     # each token-block activates top-2 experts
    mask[i, rng.choice(E, 2, replace=False)] = 1.0
acts = (rng.normal(size=(T, E * B)).astype(np.float32)
        * np.kron(mask, np.ones((B, B))))
w = rng.normal(size=(E * B, 32)).astype(np.float32)

a_sparse = pack_blockcsr(acts, B)
z_sparse = ops.spdmm(a_sparse, jnp.asarray(w), bn=8, interpret=True)
z_dense = acts @ w
print(f"block density: {a_sparse.block_density():.3f} "
      f"(stored {a_sparse.nnzb}/{(T // B) * E} blocks)")
print("sparse == dense:",
      bool(np.allclose(np.asarray(z_sparse), z_dense, atol=1e-3)))
