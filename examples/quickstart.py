"""Quickstart: dynamic sparsity-exploiting GNN inference (the paper's core).

Runs 2-layer GCN inference on synthetic Cora through the DynasparseEngine:
per-kernel density measurement -> Analyzer (STQ/DTQ) -> Scheduler -> result,
printing the runtime decisions and the estimated VCK5000 hardware time.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DynasparseEngine, VCK5000
from repro.data.graphs import load_graph
from repro.models import gnn

g = load_graph("CO")                      # |V|=2708, Table IV densities
h = g.features_dense
params = gnn.init_params("GCN", h.shape[1], g.stats.hidden, g.stats.classes)

engine = DynasparseEngine(hw=VCK5000)
logits, report = gnn.run_inference("GCN", engine, g.adj, h, params)

print(f"logits: {logits.shape}, finite: {bool(np.isfinite(np.asarray(logits)).all())}")
print(f"{'kernel':<12} {'STQ':>4} {'DTQ':>4} {'SpDMM':>6} {'SpMM':>5} "
      f"{'makespan':>12}")
for name, rep in report.kernels:
    print(f"{name:<12} {rep.n_stq:>4} {rep.n_dtq:>4} {rep.n_spdmm:>6} "
          f"{rep.n_spmm:>5} {rep.makespan * 1e6:>10.1f}us")
tot = report.total
print(f"\nend-to-end hardware time (perf model): "
      f"{report.hardware_time * 1e3:.4f} ms")
print(f"FLOPs executed {tot.flops_executed:.3g} vs dense-equivalent "
      f"{tot.flops_dense_equiv:.3g} "
      f"({tot.flops_dense_equiv / tot.flops_executed:.1f}x reduction)")
