"""End-to-end training driver demo: reduced phi3 config, checkpoint +
restart mid-run (the fault-tolerance path), loss must improve.

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys

base = [sys.executable, "-m", "repro.launch.train", "--arch",
        "phi3-mini-3.8b", "--ckpt-dir", "/tmp/repro_demo_ckpt",
        "--batch", "8", "--seq", "64"]
print(">> train 12 steps (checkpoint every 6)")
subprocess.run(base + ["--steps", "12", "--ckpt-every", "6"], check=True)
print(">> simulate preemption: resume from latest checkpoint, 6 more steps")
subprocess.run(base + ["--steps", "18", "--ckpt-every", "6", "--resume"],
               check=True)
