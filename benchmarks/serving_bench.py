"""Serving benchmark: micro-batching vs the PR-1 per-request loop.

``PYTHONPATH=src python benchmarks/serving_bench.py [--requests 32]
[--max-batch 8] [--out BENCH_serving.json]``

Three measured scenarios on ONE fixed graph (literal Pallas dispatch,
interpret mode on CPU):

1. **per_request** — the PR-1 loop: every queued request runs the full
   2-layer GCN kernel sequence (plans cached, launches not amortized).
2. **micro_batched** — the serving subsystem coalesces the same queue into
   micro-batches; one plan/execute pass per batch.  The acceptance metric
   is pallas LAUNCHES PER REQUEST, which micro-batching must reduce.
3. **density_drift** — near-dense features swapped mid-stream must trigger
   the sketch's replan AND still match the pure-jnp reference.
4. **mixed_batch** — bursts of varying size served through the padded
   single-plan path: every burst is padded to the ``max_batch`` stacked
   width (replicating its own feature columns), so the whole scenario must leave exactly ONE plan entry
   per graph in the cache (the GraphAGILE compile-once/serve-many gate)
   while still matching the per-request results.

``--scenario chaos`` (own CI lane) runs the seeded degraded-mode drill
instead: poison-request isolation, transient-fault recovery, the
compiled→eager fallback, drift-churn breaker bounds, and corrupt-snapshot
cold starts — every gate deterministic under ``--seed``.

Emits a machine-readable JSON blob (p50/p95 latency, cache hit rate,
launches per request, plans per graph, drift outcome, chaos gates) for CI
trend tracking.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DynasparseEngine, SparseCOO
from repro.kernels import ops
from repro.models import gnn
from repro.serving import (FaultInjector, InjectedFault, ServingConfig,
                           ServingEngine, SharedPlanCache, SketchConfig)
from repro.serving.faults import KNOWN_SITES


def _fixed_graph(n: int = 128, avg_deg: int = 4, seed: int = 5) -> SparseCOO:
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=avg_deg * n, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=avg_deg * n)
                                        ).astype(np.float32)),
                     tag="adjacency")


def _engine() -> DynasparseEngine:
    return DynasparseEngine(tile_m=32, tile_n=8, literal=True,
                            cache=SharedPlanCache())


def run(requests: int = 32, max_batch: int = 8, model: str = "GCN",
        feat: int = 24, hidden: int = 16) -> dict:
    assert requests >= 32, "acceptance criterion: >= 32 queued requests"
    adj = _fixed_graph()
    n = adj.shape[0]
    rng = np.random.default_rng(0)
    params = gnn.init_params(model, feat, hidden, hidden)
    batches = [rng.normal(size=(n, feat)).astype(np.float32)
               for _ in range(requests)]

    out = {"model": model, "graph_vertices": n, "requests": requests,
           "max_batch": max_batch}

    # -------- 1) PR-1 per-request loop
    eng = _engine()
    ops.reset_pallas_call_count()
    lat = []
    outs_seq = []
    t_all0 = time.perf_counter()
    for h in batches:
        t0 = time.perf_counter()
        z, _ = gnn.run_inference(model, eng, adj, jnp.asarray(h), params)
        np.asarray(z)
        lat.append(time.perf_counter() - t0)
        outs_seq.append(z)
    wall_seq = time.perf_counter() - t_all0
    out["per_request"] = {
        "pallas_launches": ops.pallas_call_count(),
        "launches_per_request": ops.pallas_call_count() / requests,
        "wall_s": wall_seq,
        "latency": {"p50": float(np.percentile(lat, 50)),
                    "p95": float(np.percentile(lat, 95))},
        "plan_hit_rate": eng.cache.stats.hit_rate,
    }

    # -------- 2) micro-batched serving over the same queue
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    ops.reset_pallas_call_count()
    t_all0 = time.perf_counter()
    outs_mb = srv.serve(("bench", h) for h in batches)
    wall_mb = time.perf_counter() - t_all0
    launches_mb = ops.pallas_call_count()
    pct = srv.stats.latency_percentiles()
    out["micro_batched"] = {
        "pallas_launches": launches_mb,
        "launches_per_request": launches_mb / requests,
        "wall_s": wall_mb,
        "latency": {"p50": pct["p50"], "p95": pct["p95"]},
        "plan_hit_rate": cache.stats.hit_rate,
        "batches": srv.stats.batches,
        "mean_batch_size": srv.stats.mean_batch_size,
        "cache_bytes": cache.bytes_used,
    }
    err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
              for a, b in zip(outs_seq, outs_mb))
    out["micro_batched"]["max_abs_err_vs_per_request"] = err
    out["launch_reduction"] = (out["per_request"]["launches_per_request"] /
                               out["micro_batched"]["launches_per_request"])
    srv.close()

    # -------- 4) mixed batch sizes through the padded single-plan path
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    sizes = [1, 3, max_batch, 2, max(1, max_batch - 1), 1, 4, max_batch]
    sizes = [max(1, min(s, max_batch)) for s in sizes]
    ops.reset_pallas_call_count()
    outs_mixed = []
    for s in sizes:
        idx = len(outs_mixed)
        outs_mixed += srv.serve(
            ("bench", batches[(idx + i) % len(batches)]) for i in range(s))
    n_mixed = len(outs_mixed)
    launches_mixed = ops.pallas_call_count()
    err_mixed = max(
        float(np.max(np.abs(np.asarray(z) -
                            np.asarray(outs_seq[i % len(outs_seq)]))))
        for i, z in enumerate(outs_mixed))
    out["mixed_batch"] = {
        "batch_sizes": sizes,
        "requests": n_mixed,
        "batches": srv.stats.batches,
        "plans_per_graph": cache.plan_count(),
        # padded partial batches must not register as density drift either:
        # one plan entry AND zero replans across mixed traffic shapes
        "replans": cache.stats.replans,
        "pallas_launches": launches_mixed,
        "launches_per_request": launches_mixed / n_mixed,
        "max_abs_err_vs_per_request": err_mixed,
    }
    srv.close()

    # -------- 3) density-drift scenario: near-dense swap mid-stream
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(
                            max_batch=1, sketch=SketchConfig(threshold=0.25)))
    srv.register_graph("bench", adj)
    sparse_h = (rng.normal(size=(n, feat)) *
                (rng.uniform(size=(n, feat)) < 0.03)).astype(np.float32)
    dense_h = rng.normal(size=(n, feat)).astype(np.float32)
    stream = [sparse_h] * 4 + [dense_h] * 4
    outs_drift = srv.serve(("bench", h) for h in stream)
    ref = gnn.run_reference(model, adj, jnp.asarray(dense_h), params)
    drift_err = float(np.max(np.abs(np.asarray(outs_drift[-1]) -
                                    np.asarray(ref))))
    out["density_drift"] = {
        "replans": cache.stats.replans,
        "replan_triggered": cache.stats.replans > 0,
        "max_abs_err_vs_reference": drift_err,
        "matches_reference": drift_err < 1e-3,
    }
    srv.close()
    return out


# --------------------------------------------------------------- chaos lane
def _chaos_serving(adj, params, model, *, faults=None, max_batch=4,
                   max_retries=2, drift=None, breaker=(3, 60.0, 30.0)):
    """Serving stack configured for the bit-equality gates: tile-aligned
    widths come from the caller, ``activation_skip`` off (the block-skip
    route's capacity decision is global, i.e. composition-dependent)."""
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True,
                           cache=SharedPlanCache())
    srv = ServingEngine(model, params, engine=eng, config=ServingConfig(
        max_batch=max_batch, sketch=SketchConfig(threshold=drift),
        activation_skip=False, max_retries=max_retries,
        breaker_threshold=breaker[0], breaker_window_s=breaker[1],
        breaker_cooldown_s=breaker[2], faults=faults))
    srv.register_graph("bench", adj)
    return srv


def run_chaos(requests: int = 32, max_batch: int = 8, model: str = "GCN",
              feat: int = 24, hidden: int = 16, seed: int = 7) -> dict:
    """Seeded degraded-mode drill.  Gates (all must hold for ``--check``):

    - LIVENESS: every request resolves (logits or structured error).
    - ISOLATION: the failed set is EXACTLY the poisoned set; every other
      request's logits are bit-identical to the fault-free reference.
    - DEGRADATION: a compiled-program fault serves its batch eagerly
      (``degraded_batches``) with zero caller-visible errors.
    - BOUNDED CHURN: oscillating density trips the breaker; compile
      invalidations stay bounded instead of growing with traffic.
    - DURABILITY: a truncated snapshot degrades to a logged cold start.
    """
    adj = _fixed_graph()
    n = adj.shape[0]
    rng = np.random.default_rng(seed)
    # hidden/out widths are multiples of tile_n (8) so no kernel column
    # tile straddles a request boundary — per-request bit-independence
    params = gnn.init_params(model, feat, hidden, hidden)
    batches = [rng.normal(size=(n, feat)).astype(np.float32)
               for _ in range(requests)]
    warm_h = [rng.normal(size=(n, feat)).astype(np.float32)
              for _ in range(max_batch)]

    def warm(srv):
        # identical warmup burst in every run: the plan is global and
        # density-dependent, so bit-equality needs the program pinned
        # from the identical operand before any chaos fires
        srv.serve(("bench", h) for h in warm_h)

    out = {"model": model, "requests": requests, "max_batch": max_batch,
           "seed": seed}

    # ---- fault-free reference (pre-warmed)
    srv = _chaos_serving(adj, params, model, max_batch=max_batch)
    warm(srv)
    t0 = time.perf_counter()
    ref = [np.asarray(z) for z in
           srv.serve(("bench", h) for h in batches)]
    ref_wall = time.perf_counter() - t0
    ref_pct = srv.stats.latency_percentiles()
    out["reference"] = {"wall_s": ref_wall,
                        "latency": {"p50": ref_pct["p50"],
                                    "p95": ref_pct["p95"]}}
    srv.close()

    # ---- isolation: poison requests + transient batch faults + straggler
    poisons = sorted(rng.choice(requests, size=3, replace=False).tolist())
    fi = (FaultInjector(seed=seed)
          .arm("dispatch", rate=1.0, count=2, after=1)   # skip warm batch
          .arm("dispatch", delay_s=0.05, count=1, after=3))
    for p in poisons:        # warmup burst consumed request ids 0..max_batch-1
        fi.arm("request", rate=1.0, match=f"req:{max_batch + p};")
    srv = _chaos_serving(adj, params, model, max_batch=max_batch, faults=fi)
    warm(srv)
    recorded_warm = len(srv.stats.requests)
    t0 = time.perf_counter()
    outs = srv.serve((("bench", h) for h in batches), return_exceptions=True)
    wall = time.perf_counter() - t0
    failed = {i for i, z in enumerate(outs) if isinstance(z, Exception)}
    bit_equal = all(
        isinstance(outs[i], InjectedFault) if i in failed
        else np.array_equal(np.asarray(outs[i]), ref[i])
        for i in range(requests))
    pct = srv.stats.latency_percentiles()
    # the ISSUE gate: non-faulted requests' p50 within budget even while
    # the ladder is bisecting/retrying around the poison requests
    ok_lat = [r.latency for r in srv.stats.requests[recorded_warm:]
              if r.error is None]
    p50_ok = float(np.percentile(ok_lat, 50)) if ok_lat else 0.0
    p50_budget = max(5.0 * out["reference"]["latency"]["p50"], 1.0)
    out["isolation"] = {
        "poisoned": poisons,
        "failed": sorted(failed),
        "all_resolved": len(outs) == requests,
        "all_recorded": len(srv.stats.requests) - recorded_warm == requests,
        "failed_set_is_poison_set": failed == set(poisons),
        "neighbours_bit_equal": bool(bit_equal),
        "quarantined": srv.stats.quarantined,
        "bisections": srv.stats.bisections,
        "retries": srv.stats.retries,
        "injected": fi.summary(),
        "wall_s": wall,
        "latency": {"p50": pct["p50"], "p95": pct["p95"]},
        "non_faulted_p50": p50_ok,
        "p50_budget_s": p50_budget,
        "p50_within_budget": p50_ok <= p50_budget,
    }
    srv.close()

    # ---- liveness: every instrumented serving site, one at a time + mixed
    live_n = min(8, requests)
    refs_live = [np.asarray(gnn.run_reference(model, adj, jnp.asarray(h),
                                              params))
                 for h in batches[:live_n]]
    site_results = {}
    sites = sorted(s for s in KNOWN_SITES if not s.startswith("snapshot"))
    for site in sites + ["mixed"]:
        if site == "mixed":
            fi = (FaultInjector(seed=seed)
                  .arm("plan", rate=0.3, count=2)
                  .arm("execute", rate=0.3, count=2)
                  .arm("compiled", rate=1.0, count=1)
                  .arm("request", rate=1.0, match="req:2;"))
        else:
            fi = FaultInjector(seed=seed).arm(site, rate=1.0, count=2)
        srv = _chaos_serving(adj, params, model, max_batch=max_batch,
                             faults=fi)
        # no pre-warm: the warmup plan/lower/pack probes must be hit too;
        # successes are gated against the eager reference (a mid-warmup
        # fault legitimately re-plans, so bit-equality is the isolation
        # run's gate, numeric correctness is this one's)
        outs = srv.serve((("bench", h) for h in batches[:live_n]),
                         return_exceptions=True)
        errs = sum(isinstance(z, Exception) for z in outs)
        correct = all(
            isinstance(z, Exception)
            or float(np.max(np.abs(np.asarray(z) - refs_live[i]))) < 1e-3
            for i, z in enumerate(outs))
        site_results[site] = {
            "resolved": len(outs), "errors": errs,
            "recorded": len(srv.stats.requests),
            "fired": fi.total_fired, "correct": correct,
            "live": len(outs) == live_n
                    and len(srv.stats.requests) == live_n and correct,
        }
        srv.close()
    out["liveness"] = {
        "requests_per_site": live_n,
        "sites": site_results,
        "all_sites_live": all(r["live"] for r in site_results.values()),
    }

    # ---- sharded chaos: mesh-enabled serving under shard_lower /
    # shard_exec faults.  The chaos lane sees one device, but a 1-device
    # mesh drives the full sharded path (band placement → halo lowering →
    # shard_map execute), so the probes genuinely fire here — unlike in
    # the meshless liveness loop above, where they are inert.
    from repro.launch.mesh import make_data_mesh
    shard_results = {}
    for site in ("shard_lower", "shard_exec"):
        fi = FaultInjector(seed=seed).arm(site, rate=1.0, count=2)
        eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True,
                               cache=SharedPlanCache(),
                               mesh=make_data_mesh(1))
        srv = ServingEngine(model, params, engine=eng,
                            config=ServingConfig(
                                max_batch=max_batch,
                                sketch=SketchConfig(threshold=None),
                                activation_skip=False, max_retries=2,
                                faults=fi))
        srv.register_graph("bench", adj)
        outs = srv.serve((("bench", h) for h in batches[:live_n]),
                         return_exceptions=True)
        correct = all(
            isinstance(z, Exception)
            or float(np.max(np.abs(np.asarray(z) - refs_live[i]))) < 1e-3
            for i, z in enumerate(outs))
        shard_results[site] = {
            "resolved": len(outs),
            "errors": sum(isinstance(z, Exception) for z in outs),
            "recorded": len(srv.stats.requests),
            "fired": fi.total_fired, "correct": correct,
            "live": len(outs) == live_n
                    and len(srv.stats.requests) == live_n and correct,
        }
        srv.close()
    out["sharded_chaos"] = {
        "requests_per_site": live_n,
        "sites": shard_results,
        "all_fired": all(r["fired"] > 0 for r in shard_results.values()),
        "all_live": all(r["live"] for r in shard_results.values()),
    }

    # ---- degradation: compiled-program fault → eager fallback, no errors
    fi = FaultInjector(seed=seed).arm("compiled", rate=1.0, count=1, after=1)
    srv = _chaos_serving(adj, params, model, max_batch=max_batch, faults=fi)
    warm(srv)
    outs = srv.serve((("bench", h) for h in batches), return_exceptions=True)
    errs = [z for z in outs if isinstance(z, Exception)]
    max_err = max(float(np.max(np.abs(np.asarray(z) - r)))
                  for z, r in zip(outs, ref))
    out["degraded"] = {
        "degraded_batches": srv.stats.degraded_batches,
        "errors": len(errs),
        # the eager fallback replans on the live operand → FP tolerance,
        # not bit-equality, for the degraded batch
        "max_abs_err_vs_reference": max_err,
        "matches_reference": max_err < 1e-3,
    }
    srv.close()

    # ---- bounded churn: oscillating density vs the circuit breaker
    sparse_h = (rng.normal(size=(n, feat)) *
                (rng.uniform(size=(n, feat)) < 0.03)).astype(np.float32)
    dense_h = rng.normal(size=(n, feat)).astype(np.float32)
    flips = [sparse_h if i % 2 == 0 else dense_h for i in range(12)]
    srv = _chaos_serving(adj, params, model, max_batch=1, drift=0.25,
                         breaker=(2, 60.0, 60.0))
    outs = srv.serve(("bench", h) for h in flips)
    churn_err = max(
        float(np.max(np.abs(np.asarray(z) - np.asarray(
            gnn.run_reference(model, adj, jnp.asarray(h), params)))))
        for h, z in zip(flips, outs))
    out["breaker"] = {
        "flips": len(flips),
        "breaker_trips": srv.stats.breaker_trips,
        "compile_invalidations": srv.stats.compile_invalidations,
        "invalidations_bounded": srv.stats.compile_invalidations <= 2,
        "max_abs_err_vs_reference": churn_err,
        "matches_reference": churn_err < 1e-3,
    }
    srv.close()

    # ---- durability: truncated snapshot must cold-start, not crash
    cache = SharedPlanCache()
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True, cache=cache)
    gnn.run_inference(model, eng, adj, jnp.asarray(batches[0]), params)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "plans.pkl")
        cache.save(path)
        blob = open(path, "rb").read()
        with open(path, "wb") as f:
            f.write(blob[:len(blob) // 2])
        fresh = SharedPlanCache()
        manifest = fresh.load(path)
        out["snapshot"] = {
            "cold_start": bool(manifest.get("cold_start")),
            "snapshot_errors": fresh.stats.snapshot_errors,
            "error": manifest.get("error"),
        }

    out["ok"] = bool(
        out["isolation"]["all_resolved"]
        and out["isolation"]["all_recorded"]
        and out["isolation"]["failed_set_is_poison_set"]
        and out["isolation"]["neighbours_bit_equal"]
        and out["isolation"]["quarantined"] == len(poisons)
        and out["isolation"]["p50_within_budget"]
        and out["liveness"]["all_sites_live"]
        and out["sharded_chaos"]["all_fired"]
        and out["sharded_chaos"]["all_live"]
        and out["degraded"]["degraded_batches"] >= 1
        and out["degraded"]["errors"] == 0
        and out["degraded"]["matches_reference"]
        and out["breaker"]["breaker_trips"] >= 1
        and out["breaker"]["invalidations_bounded"]
        and out["breaker"]["matches_reference"]
        and out["snapshot"]["cold_start"]
        and out["snapshot"]["snapshot_errors"] >= 1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--model", default="GCN")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--scenario", choices=("core", "chaos", "all"),
                    default="all",
                    help="core = throughput/drift scenarios, chaos = the "
                         "degraded-mode drill (own CI lane)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless micro-batching reduced "
                         "launches/request, the drift replan fired, and "
                         "(chaos lane) every degraded-mode gate held (CI)")
    args = ap.parse_args()

    res = {}
    if args.scenario in ("core", "all"):
        res = run(requests=args.requests, max_batch=args.max_batch,
                  model=args.model)
    if args.scenario in ("chaos", "all"):
        res["chaos"] = run_chaos(requests=args.requests,
                                 max_batch=max(2, args.max_batch // 2),
                                 model=args.model, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[serving_bench] wrote {args.out}")
    shown = [k for k in ("launch_reduction", "per_request", "micro_batched",
                         "mixed_batch", "density_drift", "chaos")
             if k in res]
    print(json.dumps({k: res[k] for k in shown}, indent=2))
    if args.check:
        ok = True
        if args.scenario in ("core", "all"):
            ok = (res["launch_reduction"] > 1.0
                  and res["density_drift"]["replan_triggered"]
                  and res["density_drift"]["matches_reference"]
                  and res["micro_batched"]["max_abs_err_vs_per_request"] < 1e-3
                  # single-plan serving: mixed batch sizes leave ONE plan
                  # entry per graph, trigger zero drift replans, and still
                  # reduce per-request pallas launches
                  and res["mixed_batch"]["plans_per_graph"] == 1
                  and res["mixed_batch"]["replans"] == 0
                  and res["mixed_batch"]["max_abs_err_vs_per_request"] < 1e-3
                  and (res["mixed_batch"]["launches_per_request"]
                       < res["per_request"]["launches_per_request"]))
        if ok and args.scenario in ("chaos", "all"):
            ok = res["chaos"]["ok"]
        if not ok:
            raise SystemExit("[serving_bench] acceptance check FAILED")
        print("[serving_bench] acceptance check passed")


if __name__ == "__main__":
    main()
