"""Serving benchmark: micro-batching vs the PR-1 per-request loop.

``PYTHONPATH=src python benchmarks/serving_bench.py [--requests 32]
[--max-batch 8] [--out BENCH_serving.json]``

Three measured scenarios on ONE fixed graph (literal Pallas dispatch,
interpret mode on CPU):

1. **per_request** — the PR-1 loop: every queued request runs the full
   2-layer GCN kernel sequence (plans cached, launches not amortized).
2. **micro_batched** — the serving subsystem coalesces the same queue into
   micro-batches; one plan/execute pass per batch.  The acceptance metric
   is pallas LAUNCHES PER REQUEST, which micro-batching must reduce.
3. **density_drift** — near-dense features swapped mid-stream must trigger
   the sketch's replan AND still match the pure-jnp reference.
4. **mixed_batch** — bursts of varying size served through the padded
   single-plan path: every burst is padded to the ``max_batch`` stacked
   width (replicating its own feature columns), so the whole scenario must leave exactly ONE plan entry
   per graph in the cache (the GraphAGILE compile-once/serve-many gate)
   while still matching the per-request results.

Emits a machine-readable JSON blob (p50/p95 latency, cache hit rate,
launches per request, plans per graph, drift outcome) for CI trend
tracking.
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DynasparseEngine, SparseCOO
from repro.kernels import ops
from repro.models import gnn
from repro.serving import (ServingConfig, ServingEngine, SharedPlanCache,
                           SketchConfig)


def _fixed_graph(n: int = 128, avg_deg: int = 4, seed: int = 5) -> SparseCOO:
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=avg_deg * n, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=avg_deg * n)
                                        ).astype(np.float32)),
                     tag="adjacency")


def _engine() -> DynasparseEngine:
    return DynasparseEngine(tile_m=32, tile_n=8, literal=True,
                            cache=SharedPlanCache())


def run(requests: int = 32, max_batch: int = 8, model: str = "GCN",
        feat: int = 24, hidden: int = 16) -> dict:
    assert requests >= 32, "acceptance criterion: >= 32 queued requests"
    adj = _fixed_graph()
    n = adj.shape[0]
    rng = np.random.default_rng(0)
    params = gnn.init_params(model, feat, hidden, hidden)
    batches = [rng.normal(size=(n, feat)).astype(np.float32)
               for _ in range(requests)]

    out = {"model": model, "graph_vertices": n, "requests": requests,
           "max_batch": max_batch}

    # -------- 1) PR-1 per-request loop
    eng = _engine()
    ops.reset_pallas_call_count()
    lat = []
    outs_seq = []
    t_all0 = time.perf_counter()
    for h in batches:
        t0 = time.perf_counter()
        z, _ = gnn.run_inference(model, eng, adj, jnp.asarray(h), params)
        np.asarray(z)
        lat.append(time.perf_counter() - t0)
        outs_seq.append(z)
    wall_seq = time.perf_counter() - t_all0
    out["per_request"] = {
        "pallas_launches": ops.pallas_call_count(),
        "launches_per_request": ops.pallas_call_count() / requests,
        "wall_s": wall_seq,
        "latency": {"p50": float(np.percentile(lat, 50)),
                    "p95": float(np.percentile(lat, 95))},
        "plan_hit_rate": eng.cache.stats.hit_rate,
    }

    # -------- 2) micro-batched serving over the same queue
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    ops.reset_pallas_call_count()
    t_all0 = time.perf_counter()
    outs_mb = srv.serve(("bench", h) for h in batches)
    wall_mb = time.perf_counter() - t_all0
    launches_mb = ops.pallas_call_count()
    pct = srv.stats.latency_percentiles()
    out["micro_batched"] = {
        "pallas_launches": launches_mb,
        "launches_per_request": launches_mb / requests,
        "wall_s": wall_mb,
        "latency": {"p50": pct["p50"], "p95": pct["p95"]},
        "plan_hit_rate": cache.stats.hit_rate,
        "batches": srv.stats.batches,
        "mean_batch_size": srv.stats.mean_batch_size,
        "cache_bytes": cache.bytes_used,
    }
    err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
              for a, b in zip(outs_seq, outs_mb))
    out["micro_batched"]["max_abs_err_vs_per_request"] = err
    out["launch_reduction"] = (out["per_request"]["launches_per_request"] /
                               out["micro_batched"]["launches_per_request"])
    srv.close()

    # -------- 4) mixed batch sizes through the padded single-plan path
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    sizes = [1, 3, max_batch, 2, max(1, max_batch - 1), 1, 4, max_batch]
    sizes = [max(1, min(s, max_batch)) for s in sizes]
    ops.reset_pallas_call_count()
    outs_mixed = []
    for s in sizes:
        idx = len(outs_mixed)
        outs_mixed += srv.serve(
            ("bench", batches[(idx + i) % len(batches)]) for i in range(s))
    n_mixed = len(outs_mixed)
    launches_mixed = ops.pallas_call_count()
    err_mixed = max(
        float(np.max(np.abs(np.asarray(z) -
                            np.asarray(outs_seq[i % len(outs_seq)]))))
        for i, z in enumerate(outs_mixed))
    out["mixed_batch"] = {
        "batch_sizes": sizes,
        "requests": n_mixed,
        "batches": srv.stats.batches,
        "plans_per_graph": cache.plan_count(),
        # padded partial batches must not register as density drift either:
        # one plan entry AND zero replans across mixed traffic shapes
        "replans": cache.stats.replans,
        "pallas_launches": launches_mixed,
        "launches_per_request": launches_mixed / n_mixed,
        "max_abs_err_vs_per_request": err_mixed,
    }
    srv.close()

    # -------- 3) density-drift scenario: near-dense swap mid-stream
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(
                            max_batch=1, sketch=SketchConfig(threshold=0.25)))
    srv.register_graph("bench", adj)
    sparse_h = (rng.normal(size=(n, feat)) *
                (rng.uniform(size=(n, feat)) < 0.03)).astype(np.float32)
    dense_h = rng.normal(size=(n, feat)).astype(np.float32)
    stream = [sparse_h] * 4 + [dense_h] * 4
    outs_drift = srv.serve(("bench", h) for h in stream)
    ref = gnn.run_reference(model, adj, jnp.asarray(dense_h), params)
    drift_err = float(np.max(np.abs(np.asarray(outs_drift[-1]) -
                                    np.asarray(ref))))
    out["density_drift"] = {
        "replans": cache.stats.replans,
        "replan_triggered": cache.stats.replans > 0,
        "max_abs_err_vs_reference": drift_err,
        "matches_reference": drift_err < 1e-3,
    }
    srv.close()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--model", default="GCN")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless micro-batching reduced "
                         "launches/request and the drift replan fired (CI)")
    args = ap.parse_args()

    res = run(requests=args.requests, max_batch=args.max_batch,
              model=args.model)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[serving_bench] wrote {args.out}")
    print(json.dumps({k: res[k] for k in
                      ("launch_reduction", "per_request", "micro_batched",
                       "mixed_batch", "density_drift")}, indent=2))
    if args.check:
        ok = (res["launch_reduction"] > 1.0
              and res["density_drift"]["replan_triggered"]
              and res["density_drift"]["matches_reference"]
              and res["micro_batched"]["max_abs_err_vs_per_request"] < 1e-3
              # single-plan serving: mixed batch sizes leave ONE plan entry
              # per graph, trigger zero drift replans, and still reduce
              # per-request pallas launches
              and res["mixed_batch"]["plans_per_graph"] == 1
              and res["mixed_batch"]["replans"] == 0
              and res["mixed_batch"]["max_abs_err_vs_per_request"] < 1e-3
              and (res["mixed_batch"]["launches_per_request"]
                   < res["per_request"]["launches_per_request"]))
        if not ok:
            raise SystemExit("[serving_bench] acceptance check FAILED")
        print("[serving_bench] acceptance check passed")


if __name__ == "__main__":
    main()
