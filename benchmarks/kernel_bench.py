"""Kernel microbenchmarks: the three Pallas primitives across density.

Wall-clock here is CPU interpret-mode (correctness path), NOT a TPU claim —
the TPU numbers are the perf-model / roofline terms also printed.  This bench
demonstrates the skip behaviour (SpDMM work scales with block density) and
the runtime tentpole: per-queue batched dispatch issues O(primitives) pallas
launches per kernel, and the PlanCache packs/analyzes a static adjacency
exactly once across layers and repeated inference calls.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DynasparseEngine, SparseCOO
from repro.core.perfmodel import TPUV5E, TaskShape, t_dense, t_spdmm
from repro.core.scheduler import execute_plan
from repro.kernels import ops
from repro.kernels.formats import pack_blockcsr
from repro.models import gnn


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        np.asarray(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps


def run(csv: list[str]) -> None:
    print("\n== Kernel μbench (interpret-mode wall; v5e model time) ==")
    rng = np.random.default_rng(0)
    m = k = n = 256
    block = 32
    y = rng.normal(size=(k, n)).astype(np.float32)

    t_g = _time(ops.gemm, jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)),
                jnp.asarray(y), bm=64, bn=64, bk=64, interpret=True)
    model_t = t_dense(TaskShape(m, k, n, 1.0, 1.0), TPUV5E)
    print(f"gemm {m}x{k}x{n}: wall {t_g * 1e6:9.1f} us | v5e model "
          f"{model_t * 1e9:7.1f} ns")
    csv.append(f"kernel/gemm_{m},{t_g * 1e6:.1f},{model_t * 1e9:.1f}")

    for density in (0.1, 0.3, 0.6, 1.0):
        mask = (rng.uniform(size=(m // block, k // block)) < density
                ).astype(np.float32)
        a_dense = (rng.normal(size=(m, k)) *
                   np.kron(mask, np.ones((block, block)))).astype(np.float32)
        a = pack_blockcsr(a_dense, block)
        t_s = _time(ops.spdmm, a, jnp.asarray(y), bn=block, interpret=True)
        alpha = a.block_density()
        model_t = t_spdmm(TaskShape(m, k, n, alpha, 1.0), TPUV5E)
        print(f"spdmm α_blk={alpha:4.2f}: wall {t_s * 1e6:9.1f} us | "
              f"v5e model {model_t * 1e9:7.1f} ns | stored blocks "
              f"{a.stored_blocks}")
        csv.append(f"kernel/spdmm_a{alpha:.2f},{t_s * 1e6:.1f},"
                   f"{model_t * 1e9:.1f}")

    _run_dispatch_bench(csv)


def _rand_adj(n: int, nnz: int, seed: int = 5) -> SparseCOO:
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=nnz, replace=False))
    return SparseCOO(
        (n, n),
        jnp.asarray((flat // n).astype(np.int32)),
        jnp.asarray((flat % n).astype(np.int32)),
        jnp.asarray(np.abs(rng.normal(size=nnz)).astype(np.float32)),
        tag="adjacency")


def _run_dispatch_bench(csv: list[str]) -> None:
    """Tentpole demo: batched per-queue dispatch + plan cache on a 2-layer
    GCN (literal Pallas execution, interpret mode)."""
    print("\n== Batched dispatch + PlanCache (2-layer GCN, literal) ==")
    rng = np.random.default_rng(0)
    n, f, hidden = 128, 24, 16
    adj = _rand_adj(n, 4 * n)
    h = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    params = gnn.init_params("GCN", f, hidden, hidden)

    # one aggregation kernel: per-task vs batched launches + wall-clock
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True)
    plan = eng.plan(adj, h)
    xd = adj.todense()
    n_tasks = len(plan.stq) + len(plan.dtq)

    def _wall(batched):
        ops.reset_pallas_call_count()
        t0 = time.perf_counter()
        z = execute_plan(plan.part, plan.stq, plan.dtq, xd, h,
                         batched=batched)
        np.asarray(z)
        return time.perf_counter() - t0, ops.pallas_call_count(), z

    w_b, calls_b, z_b = _wall(True)
    w_p, calls_p, z_p = _wall(False)
    err = float(np.max(np.abs(np.asarray(z_b) - np.asarray(z_p))))
    print(f"execute_plan agg kernel ({n_tasks} tasks): "
          f"per-task {calls_p} launches / {w_p * 1e3:7.1f} ms | "
          f"batched {calls_b} launches / {w_b * 1e3:7.1f} ms | "
          f"max |Δ| {err:.2e}")
    csv.append(f"dispatch/launches,{calls_p},{calls_b}")
    csv.append(f"dispatch/wall_ms,{w_p * 1e3:.1f},{w_b * 1e3:.1f}")

    # plan cache across layers and repeated requests
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True)
    gnn.run_inference("GCN", eng, adj, h, params)
    s1 = dataclasses.replace(eng.cache.stats)   # snapshot: stats mutate in place
    print(f"inference 1: packs={s1.packs} analyzes={s1.analyzes} "
          f"plan hits={s1.plan_hits} misses={s1.plan_misses} "
          f"(layer-2 aggregation hits the layer-1 plan)")
    gnn.run_inference("GCN", eng, adj, h, params)
    s2 = dataclasses.replace(eng.cache.stats)
    print(f"inference 2: packs={s2.packs} analyzes={s2.analyzes} "
          f"plan hits={s2.plan_hits} misses={s2.plan_misses} "
          f"(serving path: every kernel replans nothing)")
    csv.append(f"plancache/packs,{s1.packs},{s2.packs}")
    csv.append(f"plancache/plan_hits,{s1.plan_hits},{s2.plan_hits}")
