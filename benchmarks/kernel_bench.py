"""Kernel microbenchmarks: the three Pallas primitives across density.

Wall-clock here is CPU interpret-mode (correctness path), NOT a TPU claim —
the TPU numbers are the perf-model / roofline terms also printed.  This bench
demonstrates the skip behaviour: SpDMM work scales with block density.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import TPUV5E, TaskShape, t_dense, t_spdmm
from repro.kernels import ops
from repro.kernels.formats import pack_blockcsr


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        np.asarray(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps


def run(csv: list[str]) -> None:
    print("\n== Kernel μbench (interpret-mode wall; v5e model time) ==")
    rng = np.random.default_rng(0)
    m = k = n = 256
    block = 32
    y = rng.normal(size=(k, n)).astype(np.float32)

    t_g = _time(ops.gemm, jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)),
                jnp.asarray(y), bm=64, bn=64, bk=64, interpret=True)
    model_t = t_dense(TaskShape(m, k, n, 1.0, 1.0), TPUV5E)
    print(f"gemm {m}x{k}x{n}: wall {t_g * 1e6:9.1f} us | v5e model "
          f"{model_t * 1e9:7.1f} ns")
    csv.append(f"kernel/gemm_{m},{t_g * 1e6:.1f},{model_t * 1e9:.1f}")

    for density in (0.1, 0.3, 0.6, 1.0):
        mask = (rng.uniform(size=(m // block, k // block)) < density
                ).astype(np.float32)
        a_dense = (rng.normal(size=(m, k)) *
                   np.kron(mask, np.ones((block, block)))).astype(np.float32)
        a = pack_blockcsr(a_dense, block)
        t_s = _time(ops.spdmm, a, jnp.asarray(y), bn=block, interpret=True)
        alpha = a.block_density()
        model_t = t_spdmm(TaskShape(m, k, n, alpha, 1.0), TPUV5E)
        print(f"spdmm α_blk={alpha:4.2f}: wall {t_s * 1e6:9.1f} us | "
              f"v5e model {model_t * 1e9:7.1f} ns | stored blocks "
              f"{a.stored_blocks}")
        csv.append(f"kernel/spdmm_a{alpha:.2f},{t_s * 1e6:.1f},"
                   f"{model_t * 1e9:.1f}")
