"""Paper Table VIII: scaling the AIE array 192 -> 384 tiles (GCN), assuming
sufficient external memory bandwidth (paper lifts the DDR bound for the
scaled scenario; we mirror that by scaling mem_bw with the tile count)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import DSETS, replay
from repro.core.perfmodel import VCK5000, VCK5000_384

PAPER_192_MS = {"CO": 9.40e-3, "CI": 1.22e-2, "PU": 8.65e-2, "FL": 6.10e0,
                "NE": 5.20e0, "RE": 9.10e1}
PAPER_384_MS = {"CO": 9.40e-3, "CI": 1.22e-2, "PU": 8.65e-2, "FL": 2.53e0,
                "NE": 4.25e0, "RE": 7.97e1}


def run(csv: list[str]) -> None:
    print("\n== Table VIII: AIE tile scaling 192 -> 384 (GCN) ==")
    hw384 = dataclasses.replace(VCK5000_384, mem_bw=VCK5000.mem_bw * 2)
    print(f"{'ds':>3} {'192t ms':>9} {'384t ms':>9} {'speedup':>8} "
          f"{'paper speedup':>13}")
    for ds in DSETS:
        _, t192 = replay("GCN", ds, hw=VCK5000)
        _, t384 = replay("GCN", ds, hw=hw384)
        paper_spd = PAPER_192_MS[ds] / PAPER_384_MS[ds]
        print(f"{ds:>3} {t192 * 1e3:9.4g} {t384 * 1e3:9.4g} "
              f"{t192 / t384:8.2f} {paper_spd:13.2f}")
        csv.append(f"table_viii/{ds}/scale_192_384_speedup,,"
                   f"{t192 / t384:.3f}")
