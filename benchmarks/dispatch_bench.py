"""Host-dispatch overhead benchmark: compiled dispatch vs eager rebuild.

``PYTHONPATH=src python benchmarks/dispatch_bench.py [--requests 48]
[--max-batch 8] [--out BENCH_dispatch.json] [--check]``

Measures the cost this PR removes from the serving steady state — the
per-request host work of re-deriving the fused-kernel instruction stream —
and gates that it stays removed:

1. **kernel_level** — one planned aggregation kernel: descriptor-lowering
   time (``build_dispatch``, the one-time cost), eager batched execute wall
   (per-request descriptor rebuild) vs compiled execute wall (one jitted
   call), and their bit-identity.
2. **serving_steady_state** — a request stream through the ServingEngine:
   per-request latency split into warmup (first batch: plan + pack + lower
   + trace) vs steady state p50/p99, plus the compiled-path counters.
3. **sparse_activation** — a block-sparse feature stream whose sparsity
   pattern varies per request: post-warmup batches must run compiled WITH
   the capacity block-skip route active (skipped-block ratio > 0, zero
   overflows) and zero retraces across the varying patterns.
4. **calibration** — the measured performance model (ISSUE 7): a fallback
   hardware model is calibrated against the real Pallas kernels once, the
   calibrated STQ/DTQ assignment's compiled execute is timed against the
   static-guess assignment on the same kernel, and a simulated restart
   (SharedPlanCache save/load) must replay the calibration with ZERO
   re-measures.
5. **per_stripe_budget** — skew-aware activation budgets (ISSUE 7 leg 2):
   on a skewed activation the per-stripe budget vector must cut padded-slot
   waste ≥20% vs the uniform budget, overflow-free, retrace-free and
   bit-identical to the eager path.
6. **multidev** — mesh-sharded compiled dispatch (ISSUE 8): row-stripe
   bands sharded over every visible device (the CI ``multidev`` lane forces
   8 host devices).  Bit-exact vs the eager executor of the same placed
   plan, one lowering, trace-free replay, per-shard descriptor streams of
   O(global / devices).
7. **halo** — owned+halo operand distribution (ISSUE 10): on a banded
   locality graph each device holds only its owned Y block-rows plus the
   thin halo its band reads, exchanged by a static ppermute schedule inside
   the compiled program.  Bit-exact vs the replicate-everything oracle and
   the eager executor; per-device dense-operand bytes strictly below the
   replicated baseline at >= 4 devices.

``--check`` (CI) enforces the ISSUE-4/5/7 acceptance criteria: in steady
state ``dispatch_builds == plans``, ``replans == 0``, every post-warmup
micro-batch runs compiled, the jit trace cache is hit on every micro-batch
after the first compiled one, the sparse-activation scenario keeps skipping
blocks without a single replan, retrace or capacity overflow (and its
steady-state act_hits grow), calibration replays from the cache with zero
re-measures while its assignment executes no slower than the static guess,
and the per-stripe budgets hit their waste-reduction bar.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import DynasparseEngine, SparseCOO
from repro.core import calibrate
from repro.core import dispatch as dispatch_mod
from repro.core.perfmodel import runtime_fallback
from repro.core.scheduler import execute_plan
from repro.models import gnn
from repro.serving import ServingConfig, ServingEngine, SharedPlanCache


def _fixed_graph(n: int = 128, avg_deg: int = 4, seed: int = 5) -> SparseCOO:
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=avg_deg * n, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=avg_deg * n)
                                        ).astype(np.float32)),
                     tag="adjacency")


def _kernel_level(adj: SparseCOO, width: int = 16, repeats: int = 5) -> dict:
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=(adj.shape[0], width)).astype(np.float32))
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True,
                           cache=SharedPlanCache())
    plan = eng.plan(adj, y, name="agg")
    _, entry = eng._packed_structure(plan, adj)

    t0 = time.perf_counter()
    for _ in range(repeats):
        d = dispatch_mod.build_dispatch(plan.part, plan.stq, plan.dtq,
                                        entry.stripes, block=eng.block)
    build_s = (time.perf_counter() - t0) / repeats

    # eager batched: per-call descriptor rebuild (the pre-PR steady state)
    xd = None if not plan.dtq else jnp.asarray(adj.todense())
    t0 = time.perf_counter()
    for _ in range(repeats):
        z_e = execute_plan(plan.part, plan.stq, plan.dtq, xd, y,
                           block=eng.block, packed=entry.stripes)
        np.asarray(z_e)
    eager_s = (time.perf_counter() - t0) / repeats

    # compiled: warm the trace, then measure the steady-state call
    z_c = eng.execute(plan, adj, y)
    t0 = time.perf_counter()
    for _ in range(repeats):
        z_c = eng.execute(plan, adj, y)
        np.asarray(z_c)
    compiled_s = (time.perf_counter() - t0) / repeats

    return {
        "descriptor_build_s": build_s,
        "n_spdmm_entries": d.n_entries,
        "n_spmm_triples": d.n_triples,
        "eager_execute_s": eager_s,
        "compiled_execute_s": compiled_s,
        "speedup_eager_over_compiled": eager_s / max(compiled_s, 1e-12),
        "bit_identical": bool(np.array_equal(np.asarray(z_e),
                                             np.asarray(z_c))),
    }


def _serving_steady_state(adj: SparseCOO, requests: int, max_batch: int,
                          model: str, feat: int, hidden: int) -> dict:
    rng = np.random.default_rng(0)
    n = adj.shape[0]
    params = gnn.init_params(model, feat, hidden, hidden)
    batches = [rng.normal(size=(n, feat)).astype(np.float32)
               for _ in range(requests)]
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    outs = srv.serve(("bench", h) for h in batches)

    ref = gnn.run_reference(model, adj, jnp.asarray(batches[0]), params)
    err = float(np.max(np.abs(np.asarray(outs[0]) - np.asarray(ref))))

    lat = sorted(r.latency for r in srv.stats.requests)
    warm = [r.latency for r in srv.stats.requests
            if r.request_id < max_batch]            # the warmup batch
    steady = [r.latency for r in srv.stats.requests
              if r.request_id >= max_batch]
    ds = srv.dispatch_stats()
    out = {
        "requests": requests,
        "batches": srv.stats.batches,
        "compiled_batches": srv.stats.compiled_batches,
        "warmup_latency_s": float(np.mean(warm)) if warm else 0.0,
        "steady_p50_s": float(np.percentile(steady, 50)) if steady else 0.0,
        "steady_p99_s": float(np.percentile(steady, 99)) if steady else 0.0,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "max_abs_err_vs_reference": err,
        **ds,
    }
    srv.close()
    return out


def _sparse_activation(adj: SparseCOO, requests: int, max_batch: int,
                       model: str, feat: int, hidden: int) -> dict:
    """Block-sparse features with a per-request pattern wiggle: the compiled
    program must keep skipping activation blocks (ISSUE-5 tentpole) with
    zero retraces while the sparsity varies within the capacity budget."""
    rng = np.random.default_rng(3)
    n = adj.shape[0]
    params = gnn.init_params(model, feat, hidden, hidden)
    B = 8
    nrb, ncb = -(-n // B), -(-feat // B)
    mask = np.kron((rng.uniform(size=(nrb, ncb)) < 0.3).astype(np.float32),
                   np.ones((B, B)))[:n, :feat]
    batches = []
    for _ in range(requests):
        jitter = (rng.uniform(size=(n, feat)) < 0.95)
        batches.append((rng.normal(size=(n, feat)) * mask * jitter
                        ).astype(np.float32))
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    outs = srv.serve(("bench", h) for h in batches)

    ref = gnn.run_reference(model, adj, jnp.asarray(batches[0]), params)
    err = float(np.max(np.abs(np.asarray(outs[0]) - np.asarray(ref))))
    ds = srv.dispatch_stats()
    act = srv.stats.activation_batches
    out = {
        "requests": requests,
        "batches": srv.stats.batches,
        "compiled_batches": srv.stats.compiled_batches,
        "compile_invalidations": srv.stats.compile_invalidations,
        "activation_batches": len(act),
        "max_abs_err_vs_reference": err,
        **ds,
    }
    srv.close()
    return out


def _calibration(adj: SparseCOO, width: int = 16, repeats: int = 9) -> dict:
    """Measured-model scenario (ISSUE 7 tentpole): calibrate the fallback
    model on the live backend, compare the calibrated STQ/DTQ assignment's
    compiled execute against the static-guess assignment on the SAME
    kernel, and prove a restarted process replays zero measurements."""
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.normal(size=(adj.shape[0], width)).astype(np.float32))
    base = runtime_fallback(compat.backend_kind())
    cache = SharedPlanCache()
    eng_static = DynasparseEngine(base, tile_m=32, tile_n=8, literal=True,
                                  cache=cache, calibration="off")
    eng_cal = DynasparseEngine(base, tile_m=32, tile_n=8, literal=True,
                               cache=cache, calibration="auto")

    n0 = calibrate.measurement_count()
    t0 = time.perf_counter()
    plan_s = eng_static.plan(adj, y, name="agg")
    plan_c = eng_cal.plan(adj, y, name="agg")
    plan_s_total = time.perf_counter() - t0
    measured = calibrate.measurement_count() - n0
    hw = eng_cal.runtime_hw()

    def _timed(eng, plan):
        z = eng.execute(plan, adj, y)          # warm the trace
        np.asarray(z)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            z = eng.execute(plan, adj, y)
            np.asarray(z)
            best = min(best, time.perf_counter() - t0)
        return best, z

    static_s, z_s = _timed(eng_static, plan_s)
    calib_s, z_c = _timed(eng_cal, plan_c)
    ref = np.asarray(adj.todense()) @ np.asarray(y)
    err = max(float(np.max(np.abs(np.asarray(z_s) - ref))),
              float(np.max(np.abs(np.asarray(z_c) - ref))))

    # simulated restart: a fresh cache loaded from the snapshot resolves
    # the calibrated model without touching a single kernel
    with tempfile.TemporaryDirectory() as td:
        snap = os.path.join(td, "cache.pkl")
        cache.save(snap)
        fresh = SharedPlanCache()
        fresh.load(snap)
        n1 = calibrate.measurement_count()
        eng_restart = DynasparseEngine(base, tile_m=32, tile_n=8,
                                       literal=True, cache=fresh,
                                       calibration="auto")
        restored = eng_restart.runtime_hw()
        re_measures = calibrate.measurement_count() - n1
        replay = {
            "re_measures_after_restart": re_measures,
            "restart_calib_builds": fresh.stats.calib_builds,
            "restart_calib_hits": fresh.stats.calib_hits,
            "model_restored": bool(restored == hw),
        }

    return {
        "backend": compat.backend_kind(),
        "base_model": base.name,
        "calibrated_model": hw.name,
        # CI caches the snapshot file: warm runs legitimately measure 0
        "snapshot_env_set": bool(os.environ.get(calibrate.SNAPSHOT_ENV)),
        "measurements": measured,
        "n_samples": hw.n_samples,
        "fit_residual": hw.fit_residual,
        "gemm_s_per_mac": hw.gemm_s_per_mac,
        "spdmm_s_per_mac": hw.spdmm_s_per_mac,
        "spmm_s_per_mac": hw.spmm_s_per_mac,
        "dispatch_overhead_s": hw.dispatch_overhead,
        "mem_bw_bytes_s": hw.mem_bw,
        "roofline_bw_ratio": hw.roofline_bw_ratio,
        "plan_and_calibrate_s": plan_s_total,
        "static_n_stq": len(plan_s.stq),
        "static_n_dtq": len(plan_s.dtq),
        "calibrated_n_stq": len(plan_c.stq),
        "calibrated_n_dtq": len(plan_c.dtq),
        "assignment_differs": ([t.queue for t in plan_s.part.tasks]
                               != [t.queue for t in plan_c.part.tasks]),
        "static_execute_s": static_s,
        "calibrated_execute_s": calib_s,
        "max_abs_err_vs_reference": err,
        "calib_builds": cache.stats.calib_builds,
        "calib_hits": cache.stats.calib_hits,
        **replay,
    }


def _per_stripe_budget(repeats: int = 4) -> dict:
    """Skew-aware budget scenario (ISSUE 7 leg 2): one dense row-stripe,
    the rest nearly empty.  The uniform budget pads every stripe to the
    dense one's need; the per-stripe vector pays each stripe its own."""
    rng = np.random.default_rng(7)
    m, k, width = 96, 64, 16
    x = np.zeros((m, k), np.float32)
    x[:16] = rng.normal(size=(16, k)).astype(np.float32)
    B = 8
    nrb, ncb = (m - 16) // B, k // B
    mask = np.kron((rng.uniform(size=(nrb, ncb)) < 0.06).astype(np.float32),
                   np.ones((B, B)))
    x[16:] = (rng.normal(size=(m - 16, k)) * mask).astype(np.float32)
    y = rng.normal(size=(k, width)).astype(np.float32)

    eng = DynasparseEngine(tile_m=16, tile_n=8, literal=True,
                           cache=SharedPlanCache())
    plan = eng.plan(x, jnp.asarray(y), name="act")
    ad_u = eng.activation_dispatch_for(plan, x, per_stripe=False)
    ad_v = eng.activation_dispatch_for(plan, x, per_stripe=True)
    if ad_u is None or ad_v is None:
        return {"skipped": "plan routed no sparse tasks"}
    stats = eng.cache.stats

    def _run(ad):
        # warmup batch (x itself) then jittered batches: the single trace
        # must serve all of them, budget never overflowing
        tb0 = stats.trace_builds
        z0, diag0 = dispatch_mod.execute_activation(
            ad, x, y, interpret=True, stats=stats)
        overflows = int(bool(diag0["overflow"]))
        for keep in rng.uniform(size=(repeats, m, k)) < 0.9:
            xi = (x * keep).astype(np.float32)
            _, diag = dispatch_mod.execute_activation(
                ad, xi, y, interpret=True, stats=stats)
            overflows += int(bool(diag["overflow"]))
        return np.asarray(z0), diag0, overflows, stats.trace_builds - tb0

    z_u, diag_u, ovf_u, traces_u = _run(ad_u)
    z_v, diag_v, ovf_v, traces_v = _run(ad_v)
    z_eager = np.asarray(execute_plan(plan.part, plan.stq, plan.dtq,
                                      x, y, batched=True, eps=eng.eps))

    stored = int(diag_v["stored"])          # same warmup x on both routes
    logical = int(diag_v["logical"])
    cap_u, cap_v = int(diag_u["capacity"]), int(diag_v["capacity"])
    waste_u = (cap_u - stored) / max(logical, 1)
    waste_v = (cap_v - stored) / max(logical, 1)
    return {
        "uniform_slots": ad_u.geom.total_slots,
        "per_stripe_slots": ad_v.geom.total_slots,
        "budgets": list(map(int, ad_v.geom.cap_vec)),
        "stored_blocks": stored,
        "logical_blocks": logical,
        "padded_waste_uniform": waste_u,
        "padded_waste_per_stripe": waste_v,
        "waste_reduction": 1.0 - waste_v / max(waste_u, 1e-12),
        "overflows": ovf_u + ovf_v,
        # one trace per route, every jittered batch replayed trace-free
        "retraces": max(0, traces_u - 1) + max(0, traces_v - 1),
        "bit_identical_to_eager": bool(
            np.array_equal(z_u, z_eager) and np.array_equal(z_v, z_eager)),
    }


def _multidev(adj: SparseCOO, width: int = 16, repeats: int = 5) -> dict:
    """Mesh-sharded dispatch scenario (ISSUE 8): the engine shards the
    row-stripe bands over every visible device (1 in the default lane, 8 in
    the CI ``multidev`` lane via XLA_FLAGS).  The sharded compiled execute
    must be bit-exact vs the eager executor of the SAME placed plan, lower
    the plan exactly once, replay trace-free, and each shard must carry
    O(descriptors / device) — not the global stream."""
    import jax

    from repro.launch.mesh import make_data_mesh

    nd = len(jax.devices())
    rng = np.random.default_rng(2)
    y = jnp.asarray(rng.normal(size=(adj.shape[0], width)).astype(np.float32))
    cache = SharedPlanCache()
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True, cache=cache,
                           mesh=make_data_mesh(nd))
    plan = eng.plan(adj, y, name="agg")
    _, entry = eng._packed_structure(plan, adj)

    # eager executor of the SAME placed plan — the bit-identity oracle
    xd = None if not plan.dtq else jnp.asarray(adj.todense())
    z_e = execute_plan(plan.part, plan.stq, plan.dtq, xd, y,
                       block=eng.block, batched=True, packed=entry.stripes,
                       eps=eng.eps)

    z_c = eng.execute(plan, adj, y)          # warm: lower + trace once
    tb0 = cache.stats.trace_builds
    t0 = time.perf_counter()
    for _ in range(repeats):
        z_c = eng.execute(plan, adj, y)
        np.asarray(z_c)
    compiled_s = (time.perf_counter() - t0) / repeats
    retraces = cache.stats.trace_builds - tb0

    # per-shard instruction stream vs the global single-device stream
    sd = eng.sharded_dispatch_for(plan, adj)
    per_dev = 0
    for k in ("sp_a_ids", "mm_a_ids", "gemm_rows"):
        if k in sd.arrays:
            per_dev += int(sd.arrays[k].shape[-1])
    d_global = dispatch_mod.build_dispatch(plan.part, plan.stq, plan.dtq,
                                           entry.stripes, block=eng.block)
    global_desc = d_global.n_entries + d_global.n_triples
    if "gemm_rows" in d_global.arrays:
        global_desc += int(d_global.arrays["gemm_rows"].shape[-1])

    return {
        "n_devices": nd,
        "band_sizes": list(plan.placement.band_sizes()),
        "per_device_descriptors": per_dev,
        "global_descriptors": global_desc,
        "sharded_dispatches": cache.sharded_count(),
        "dispatch_builds": cache.stats.dispatch_builds,
        "dispatch_hits": cache.stats.dispatch_hits,
        "retraces_after_warmup": retraces,
        "compiled_execute_s": compiled_s,
        "bit_identical_to_eager": bool(np.array_equal(np.asarray(z_e),
                                                      np.asarray(z_c))),
    }


def _halo(width: int = 16, repeats: int = 5) -> dict:
    """Owned+halo operand scenario (ISSUE 10): a banded locality graph
    (every edge within a fixed row distance) sharded over every visible
    device with ``operand_sharding="halo"`` against the
    replicate-everything oracle and the eager executor of the same placed
    plan.  Gates: bitwise identity both ways, exactly one lowering replayed
    trace-free, and — once there are >= 4 devices — per-device dense-operand
    residency strictly below the replicated baseline (each device holds its
    own row blocks plus a thin halo, not all of Y)."""
    import jax

    from repro.launch.mesh import make_data_mesh

    nd = len(jax.devices())
    # banded graph: |row - col| < 24 keeps most referenced Y rows inside
    # the owning band, so the halo is genuinely thin
    n, deg, bwidth = 256, 6, 24
    rng = np.random.default_rng(4)
    rows = np.sort(rng.integers(0, n, deg * n)).astype(np.int32)
    offs = rng.integers(-bwidth, bwidth + 1, deg * n)
    cols = np.clip(rows + offs, 0, n - 1).astype(np.int32)
    vals = np.abs(rng.normal(size=deg * n)).astype(np.float32)
    adj = SparseCOO((n, n), jnp.asarray(rows), jnp.asarray(cols),
                    jnp.asarray(vals), tag="adjacency")
    y = jnp.asarray(rng.normal(size=(n, width)).astype(np.float32))

    mesh = make_data_mesh(nd)
    cache = SharedPlanCache()
    eng_h = DynasparseEngine(tile_m=32, tile_n=8, literal=True, cache=cache,
                             mesh=mesh)                    # halo default
    eng_r = DynasparseEngine(tile_m=32, tile_n=8, literal=True,
                             cache=SharedPlanCache(), mesh=mesh,
                             operand_sharding="replicate")
    plan = eng_h.plan(adj, y, name="agg")
    _, entry = eng_h._packed_structure(plan, adj)

    xd = None if not plan.dtq else jnp.asarray(adj.todense())
    z_e = execute_plan(plan.part, plan.stq, plan.dtq, xd, y,
                       block=eng_h.block, batched=True,
                       packed=entry.stripes, eps=eng_h.eps)
    z_r = eng_r.execute(eng_r.plan(adj, y, name="agg"), adj, y)

    z_h = eng_h.execute(plan, adj, y)         # warm: lower + trace once
    tb0 = cache.stats.trace_builds
    t0 = time.perf_counter()
    for _ in range(repeats):
        z_h = eng_h.execute(plan, adj, y)
        np.asarray(z_h)
    compiled_s = (time.perf_counter() - t0) / repeats
    retraces = cache.stats.trace_builds - tb0

    sd = eng_h.sharded_dispatch_for(plan, adj)
    ob = sd.operand_bytes
    return {
        "n_devices": nd,
        "graph_vertices": n,
        "graph_bandwidth_rows": bwidth,
        "band_sizes": list(plan.placement.band_sizes()),
        "halo_blocks_total": sum(len(cs.halo) for cs in sd.supports),
        "exchange_rounds": int(sd.halo.n_rounds) if sd.halo else 0,
        "owned_bytes": ob["owned_bytes"],
        "halo_bytes": ob["halo_bytes"],
        "fallback_bytes": ob["fallback_bytes"],
        "per_device_bytes_halo": ob["halo_per_device_bytes"],
        "per_device_bytes_replicated": ob["replicated_per_device_bytes"],
        "halo_bytes_ratio": (ob["halo_per_device_bytes"]
                             / max(ob["replicated_per_device_bytes"], 1)),
        "sharded_dispatches": cache.sharded_count(),
        "retraces_after_warmup": retraces,
        "compiled_execute_s": compiled_s,
        "bit_identical_to_replicated": bool(
            np.array_equal(np.asarray(z_h), np.asarray(z_r))),
        "bit_identical_to_eager": bool(
            np.array_equal(np.asarray(z_h), np.asarray(z_e))),
    }


def run(requests: int = 48, max_batch: int = 8, model: str = "GCN",
        feat: int = 24, hidden: int = 16) -> dict:
    adj = _fixed_graph()
    return {
        "model": model,
        "graph_vertices": adj.shape[0],
        "max_batch": max_batch,
        "kernel_level": _kernel_level(adj),
        "serving_steady_state": _serving_steady_state(
            adj, requests, max_batch, model, feat, hidden),
        "sparse_activation": _sparse_activation(
            adj, requests, max_batch, model, feat, hidden),
        "calibration": _calibration(adj),
        "per_stripe_budget": _per_stripe_budget(),
        "multidev": _multidev(adj),
        "halo": _halo(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--model", default="GCN")
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the steady state is fully "
                         "compiled: dispatch_builds == plans, replans == 0, "
                         "every post-warmup batch compiled + trace-cache hit")
    args = ap.parse_args()

    res = run(requests=args.requests, max_batch=args.max_batch,
              model=args.model)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[dispatch_bench] wrote {args.out}")
    print(json.dumps(res, indent=2))
    if args.check:
        k = res["kernel_level"]
        s = res["serving_steady_state"]
        a = res["sparse_activation"]
        ok = (k["bit_identical"]
              and s["max_abs_err_vs_reference"] < 1e-3
              # every plan was lowered exactly once; nothing re-derived
              and s["dispatch_builds"] == s["plans"]
              and s["replans"] == 0
              # every batch after the warmup ran as one compiled call...
              and s["compiled_batches"] == s["batches"] - 1
              # ...and every compiled batch after the first hit the trace
              and s["trace_cache_hits"] >= s["compiled_batches"] - 1
              and s["trace_cache_hits"] > 0)
        # sparse-activation route (ISSUE 5): post-warmup batches keep the
        # block-skip active across varying patterns — no replans, no
        # retraces (the single warmup trace serves every batch), no
        # capacity overflows, and a real skipped-block ratio
        ok = (ok
              and a["max_abs_err_vs_reference"] < 1e-3
              and a["compiled_batches"] == a["batches"] - 1
              and a["activation_batches"] == a["compiled_batches"]
              and a["act_kernels_last"] >= 1
              and a["act_skipped_ratio_mean"] > 0.0
              and a["act_overflows"] == 0
              and a["replans"] == 0
              and a["compile_invalidations"] == 0
              and a["trace_cache_hits"] >= a["compiled_batches"] - 1
              # steady-state calls must CREDIT the cached act dispatches
              and a["act_hits"] > 0)
        # calibration (ISSUE 7 tentpole): the model was actually measured
        # (unless replayed from a CI-cached snapshot), the calibrated
        # assignment's compiled execute is no slower than the static guess,
        # and a restarted process replays with ZERO re-measures
        c = res["calibration"]
        ok = (ok
              and (c["measurements"] > 0 or c["snapshot_env_set"])
              and c["max_abs_err_vs_reference"] < 1e-3
              # noise guard: min-of-9 on a ~2 ms kernel still jitters
              and c["calibrated_execute_s"]
                  <= c["static_execute_s"] * 1.10 + 3e-4
              and c["re_measures_after_restart"] == 0
              and c["restart_calib_builds"] == 0
              and c["restart_calib_hits"] == 1
              and c["model_restored"])
        # per-stripe budgets (ISSUE 7 leg 2): ≥20% less padded-slot waste
        # than the uniform budget, overflow-free, retrace-free, bit-exact
        p = res["per_stripe_budget"]
        ok = (ok
              and "skipped" not in p
              and p["padded_waste_per_stripe"]
                  <= 0.8 * p["padded_waste_uniform"]
              and p["overflows"] == 0
              and p["retraces"] == 0
              and p["bit_identical_to_eager"])
        # mesh-sharded dispatch (ISSUE 8): bit-exact vs the eager executor
        # of the same placed plan, exactly one lowering replayed trace-free
        # on every later call, and each shard carries O(descriptors/device)
        # — strictly fewer than the global stream once there are >= 4 bands
        m = res["multidev"]
        ok = (ok
              and m["bit_identical_to_eager"]
              and m["sharded_dispatches"] == 1
              and m["retraces_after_warmup"] == 0
              and m["dispatch_hits"] > 0
              and sum(m["band_sizes"]) > 0
              and (m["n_devices"] < 4
                   or m["per_device_descriptors"]
                       < m["global_descriptors"]))
        # owned+halo operands (ISSUE 10): bit-exact vs BOTH the replicated
        # oracle and the eager executor, one lowering replayed trace-free,
        # and per-device dense-operand residency strictly sublinear (the
        # memory headline) once there are >= 4 devices — at 1 device the
        # owned+halo buffer plus the input slab legitimately exceeds one
        # replicated copy
        h = res["halo"]
        ok = (ok
              and h["bit_identical_to_replicated"]
              and h["bit_identical_to_eager"]
              and h["sharded_dispatches"] == 1
              and h["retraces_after_warmup"] == 0
              and (h["n_devices"] < 4 or h["halo_bytes_ratio"] < 1.0))
        if not ok:
            raise SystemExit("[dispatch_bench] acceptance check FAILED")
        print("[dispatch_bench] acceptance check passed")


if __name__ == "__main__":
    main()
