"""Host-dispatch overhead benchmark: compiled dispatch vs eager rebuild.

``PYTHONPATH=src python benchmarks/dispatch_bench.py [--requests 48]
[--max-batch 8] [--out BENCH_dispatch.json] [--check]``

Measures the cost this PR removes from the serving steady state — the
per-request host work of re-deriving the fused-kernel instruction stream —
and gates that it stays removed:

1. **kernel_level** — one planned aggregation kernel: descriptor-lowering
   time (``build_dispatch``, the one-time cost), eager batched execute wall
   (per-request descriptor rebuild) vs compiled execute wall (one jitted
   call), and their bit-identity.
2. **serving_steady_state** — a request stream through the ServingEngine:
   per-request latency split into warmup (first batch: plan + pack + lower
   + trace) vs steady state p50/p99, plus the compiled-path counters.
3. **sparse_activation** — a block-sparse feature stream whose sparsity
   pattern varies per request: post-warmup batches must run compiled WITH
   the capacity block-skip route active (skipped-block ratio > 0, zero
   overflows) and zero retraces across the varying patterns.

``--check`` (CI) enforces the ISSUE-4/5 acceptance criteria: in steady state
``dispatch_builds == plans``, ``replans == 0``, every post-warmup micro-batch
runs compiled, the jit trace cache is hit on every micro-batch after the
first compiled one, and the sparse-activation scenario keeps skipping blocks
without a single replan, retrace or capacity overflow.
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DynasparseEngine, SparseCOO
from repro.core import dispatch as dispatch_mod
from repro.core.scheduler import execute_plan
from repro.models import gnn
from repro.serving import ServingConfig, ServingEngine, SharedPlanCache


def _fixed_graph(n: int = 128, avg_deg: int = 4, seed: int = 5) -> SparseCOO:
    rng = np.random.default_rng(seed)
    flat = np.sort(rng.choice(n * n, size=avg_deg * n, replace=False))
    return SparseCOO((n, n),
                     jnp.asarray((flat // n).astype(np.int32)),
                     jnp.asarray((flat % n).astype(np.int32)),
                     jnp.asarray(np.abs(rng.normal(size=avg_deg * n)
                                        ).astype(np.float32)),
                     tag="adjacency")


def _kernel_level(adj: SparseCOO, width: int = 16, repeats: int = 5) -> dict:
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=(adj.shape[0], width)).astype(np.float32))
    eng = DynasparseEngine(tile_m=32, tile_n=8, literal=True,
                           cache=SharedPlanCache())
    plan = eng.plan(adj, y, name="agg")
    _, entry = eng._packed_structure(plan, adj)

    t0 = time.perf_counter()
    for _ in range(repeats):
        d = dispatch_mod.build_dispatch(plan.part, plan.stq, plan.dtq,
                                        entry.stripes, block=eng.block)
    build_s = (time.perf_counter() - t0) / repeats

    # eager batched: per-call descriptor rebuild (the pre-PR steady state)
    xd = None if not plan.dtq else jnp.asarray(adj.todense())
    t0 = time.perf_counter()
    for _ in range(repeats):
        z_e = execute_plan(plan.part, plan.stq, plan.dtq, xd, y,
                           block=eng.block, packed=entry.stripes)
        np.asarray(z_e)
    eager_s = (time.perf_counter() - t0) / repeats

    # compiled: warm the trace, then measure the steady-state call
    z_c = eng.execute(plan, adj, y)
    t0 = time.perf_counter()
    for _ in range(repeats):
        z_c = eng.execute(plan, adj, y)
        np.asarray(z_c)
    compiled_s = (time.perf_counter() - t0) / repeats

    return {
        "descriptor_build_s": build_s,
        "n_spdmm_entries": d.n_entries,
        "n_spmm_triples": d.n_triples,
        "eager_execute_s": eager_s,
        "compiled_execute_s": compiled_s,
        "speedup_eager_over_compiled": eager_s / max(compiled_s, 1e-12),
        "bit_identical": bool(np.array_equal(np.asarray(z_e),
                                             np.asarray(z_c))),
    }


def _serving_steady_state(adj: SparseCOO, requests: int, max_batch: int,
                          model: str, feat: int, hidden: int) -> dict:
    rng = np.random.default_rng(0)
    n = adj.shape[0]
    params = gnn.init_params(model, feat, hidden, hidden)
    batches = [rng.normal(size=(n, feat)).astype(np.float32)
               for _ in range(requests)]
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    outs = srv.serve(("bench", h) for h in batches)

    ref = gnn.run_reference(model, adj, jnp.asarray(batches[0]), params)
    err = float(np.max(np.abs(np.asarray(outs[0]) - np.asarray(ref))))

    lat = sorted(r.latency for r in srv.stats.requests)
    warm = [r.latency for r in srv.stats.requests
            if r.request_id < max_batch]            # the warmup batch
    steady = [r.latency for r in srv.stats.requests
              if r.request_id >= max_batch]
    ds = srv.dispatch_stats()
    out = {
        "requests": requests,
        "batches": srv.stats.batches,
        "compiled_batches": srv.stats.compiled_batches,
        "warmup_latency_s": float(np.mean(warm)) if warm else 0.0,
        "steady_p50_s": float(np.percentile(steady, 50)) if steady else 0.0,
        "steady_p99_s": float(np.percentile(steady, 99)) if steady else 0.0,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "max_abs_err_vs_reference": err,
        **ds,
    }
    srv.close()
    return out


def _sparse_activation(adj: SparseCOO, requests: int, max_batch: int,
                       model: str, feat: int, hidden: int) -> dict:
    """Block-sparse features with a per-request pattern wiggle: the compiled
    program must keep skipping activation blocks (ISSUE-5 tentpole) with
    zero retraces while the sparsity varies within the capacity budget."""
    rng = np.random.default_rng(3)
    n = adj.shape[0]
    params = gnn.init_params(model, feat, hidden, hidden)
    B = 8
    nrb, ncb = -(-n // B), -(-feat // B)
    mask = np.kron((rng.uniform(size=(nrb, ncb)) < 0.3).astype(np.float32),
                   np.ones((B, B)))[:n, :feat]
    batches = []
    for _ in range(requests):
        jitter = (rng.uniform(size=(n, feat)) < 0.95)
        batches.append((rng.normal(size=(n, feat)) * mask * jitter
                        ).astype(np.float32))
    cache = SharedPlanCache()
    srv = ServingEngine(model, params,
                        engine=DynasparseEngine(tile_m=32, tile_n=8,
                                                literal=True, cache=cache),
                        config=ServingConfig(max_batch=max_batch))
    srv.register_graph("bench", adj)
    outs = srv.serve(("bench", h) for h in batches)

    ref = gnn.run_reference(model, adj, jnp.asarray(batches[0]), params)
    err = float(np.max(np.abs(np.asarray(outs[0]) - np.asarray(ref))))
    ds = srv.dispatch_stats()
    act = srv.stats.activation_batches
    out = {
        "requests": requests,
        "batches": srv.stats.batches,
        "compiled_batches": srv.stats.compiled_batches,
        "compile_invalidations": srv.stats.compile_invalidations,
        "activation_batches": len(act),
        "max_abs_err_vs_reference": err,
        **ds,
    }
    srv.close()
    return out


def run(requests: int = 48, max_batch: int = 8, model: str = "GCN",
        feat: int = 24, hidden: int = 16) -> dict:
    adj = _fixed_graph()
    return {
        "model": model,
        "graph_vertices": adj.shape[0],
        "max_batch": max_batch,
        "kernel_level": _kernel_level(adj),
        "serving_steady_state": _serving_steady_state(
            adj, requests, max_batch, model, feat, hidden),
        "sparse_activation": _sparse_activation(
            adj, requests, max_batch, model, feat, hidden),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--model", default="GCN")
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the steady state is fully "
                         "compiled: dispatch_builds == plans, replans == 0, "
                         "every post-warmup batch compiled + trace-cache hit")
    args = ap.parse_args()

    res = run(requests=args.requests, max_batch=args.max_batch,
              model=args.model)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[dispatch_bench] wrote {args.out}")
    print(json.dumps(res, indent=2))
    if args.check:
        k = res["kernel_level"]
        s = res["serving_steady_state"]
        a = res["sparse_activation"]
        ok = (k["bit_identical"]
              and s["max_abs_err_vs_reference"] < 1e-3
              # every plan was lowered exactly once; nothing re-derived
              and s["dispatch_builds"] == s["plans"]
              and s["replans"] == 0
              # every batch after the warmup ran as one compiled call...
              and s["compiled_batches"] == s["batches"] - 1
              # ...and every compiled batch after the first hit the trace
              and s["trace_cache_hits"] >= s["compiled_batches"] - 1
              and s["trace_cache_hits"] > 0)
        # sparse-activation route (ISSUE 5): post-warmup batches keep the
        # block-skip active across varying patterns — no replans, no
        # retraces (the single warmup trace serves every batch), no
        # capacity overflows, and a real skipped-block ratio
        ok = (ok
              and a["max_abs_err_vs_reference"] < 1e-3
              and a["compiled_batches"] == a["batches"] - 1
              and a["activation_batches"] == a["compiled_batches"]
              and a["act_kernels_last"] >= 1
              and a["act_skipped_ratio_mean"] > 0.0
              and a["act_overflows"] == 0
              and a["replans"] == 0
              and a["compile_invalidations"] == 0
              and a["trace_cache_hits"] >= a["compiled_batches"] - 1)
        if not ok:
            raise SystemExit("[dispatch_bench] acceptance check FAILED")
        print("[dispatch_bench] acceptance check passed")


if __name__ == "__main__":
    main()
