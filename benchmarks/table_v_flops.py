"""Paper Table V: FLOPs and data count exploiting sparsity in feature
matrices (FMs) and adjacency matrix (AM) for GCN inference.

"Sp. AM" exploits adjacency sparsity only (features treated dense);
"Sp. AM + FMs" is the full dynamic analyzer.  Reduction factor = ratio.
"""
from __future__ import annotations

from benchmarks.common import DSETS, replay

PAPER_FLOPS_REDUCTION = {"CO": 48.6, "CI": 95.5, "PU": 8.8, "FL": 2.1,
                         "NE": 9.7, "RE": 1.0}
PAPER_DATA_REDUCTION = {"CO": 20.9, "CI": 43.5, "PU": 6.0, "FL": 1.8,
                        "NE": 9.2, "RE": 1.1}


def run(csv: list[str]) -> None:
    print("\n== Table V: FLOPs / data reduction from feature-matrix sparsity"
          " (GCN) ==")
    print(f"{'ds':>3} {'FLOPs am':>10} {'FLOPs am+fm':>11} {'red.':>6} "
          f"{'paper':>6} | {'data am':>10} {'data am+fm':>10} {'red.':>6} "
          f"{'paper':>6}")
    for ds in DSETS:
        # Table V is an ANALYTICAL accounting of what sparsity exploitation
        # saves (independent of engine placement): count FLOPs/data with the
        # sparse primitives applied wherever an operand is sparse
        # (mode="sparse_only"), under the two sparsity-visibility scenarios.
        am, _ = replay("GCN", ds, mode="sparse_only", densify_features=True)
        amfm, _ = replay("GCN", ds, mode="sparse_only",
                         densify_features=False)
        fr = am.flops_executed / max(amfm.flops_executed, 1)
        dr = am.data_loaded / max(amfm.data_loaded, 1)
        print(f"{ds:>3} {am.flops_executed:10.3g} {amfm.flops_executed:11.3g} "
              f"{fr:6.1f} {PAPER_FLOPS_REDUCTION[ds]:6.1f} | "
              f"{am.data_loaded:10.3g} {amfm.data_loaded:10.3g} "
              f"{dr:6.1f} {PAPER_DATA_REDUCTION[ds]:6.1f}")
        csv.append(f"table_v/{ds}/flops_reduction,,{fr:.3f}")
        csv.append(f"table_v/{ds}/data_reduction,,{dr:.3f}")
