"""Paper §IV-E: preprocessing + runtime-system overhead.

Preprocessing = 2-D partitioning / packing on the host (Fig. 6 compares
against H-GCN's partitioner; we report our absolute host cost).  Runtime
overhead = wall time of Analyzer + Scheduler (Alg. 4) relative to the
estimated hardware execution time — the paper claims < 1% after overlap.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import DSETS, replay, record
from repro.core.analyzer import analyze_kernel
from repro.core.partition import make_tasks
from repro.core.perfmodel import VCK5000
from repro.core.scheduler import simulate
from repro.data.graphs import load_graph
from repro.kernels.formats import pack_blockcsr


def run(csv: list[str]) -> None:
    print("\n== §IV-E: preprocessing + runtime-system overhead ==")
    print(f"{'ds':>3} {'preproc ms':>11} {'runtime ms':>11} {'hw ms':>10} "
          f"{'runtime/hw':>10}")
    for ds in DSETS:
        # preprocessing: partition + pack a representative feature stripe
        g = load_graph(ds, scale=min(1.0, 0.05))
        h = np.asarray(g.features_dense)[:512, :512]
        t0 = time.perf_counter()
        pack_blockcsr(h, 128)
        preproc = time.perf_counter() - t0

        # runtime system: analyzer + scheduler wall time on the full-scale
        # task grid of one aggregation kernel
        rec = record("GCN", ds)
        meta = next(m for m in rec.kernels if m["x_is_adj"])
        from benchmarks.common import full_adj_stripe_density, DATASETS
        stats = DATASETS[ds]
        tm = max(128, stats.vertices // 8)
        row_d, _ = full_adj_stripe_density(ds, tm)
        t0 = time.perf_counter()
        part = make_tasks("agg", stats.vertices, stats.vertices,
                          stats.hidden, row_d,
                          np.full(1, meta["alpha_y"]), tm, stats.hidden)
        stq, dtq = analyze_kernel(part, VCK5000)
        simulate(stq, dtq, VCK5000)
        runtime = time.perf_counter() - t0

        _, hw_time = replay("GCN", ds)
        frac = runtime / max(hw_time, 1e-12)
        print(f"{ds:>3} {preproc * 1e3:11.3f} {runtime * 1e3:11.3f} "
              f"{hw_time * 1e3:10.4g} {frac:10.2f}")
        csv.append(f"overheads/{ds}/runtime_over_hw,,{frac:.4f}")
