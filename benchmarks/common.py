"""Shared machinery for the paper-table benchmarks.

Methodology (mirrors the paper §IV-A): hardware execution time comes from the
calibrated performance-model simulator driven by REAL measured densities.
Functional inference runs at ``functional_scale`` (full scale for the small
datasets; reduced for Flickr/NELL-GIN/Reddit where a single CPU core cannot
execute the full graph), recording every kernel's geometry and measured
operand densities; the recording is then REPLAYED at full-scale geometry —
adjacency stripe densities come from the full-scale generator (exact), feature
densities from the measurement (intermediate activation density is
scale-invariant to first order).  Wall-clock of the functional JAX path is
also reported (CPU measurement, not a TPU claim).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.core import DynasparseEngine
from repro.core.analyzer import analyze_kernel, force_queue
from repro.core.partition import choose_tile, make_tasks
from repro.core.perfmodel import VCK5000, HardwareModel
from repro.core.scheduler import ScheduleReport, simulate
from repro.data.graphs import DATASETS, load_graph, _gen_edges
from repro.models import gnn

import zlib


# functional-execution scale per dataset (1.0 = full graph on CPU)
FUNCTIONAL_SCALE: dict[str, float] = {
    "CO": 1.0, "CI": 1.0, "PU": 1.0, "FL": 0.25, "NE": 0.1, "RE": 0.02,
}
# overrides where a model's structure pins aggregation to the raw features
SCALE_OVERRIDE: dict[tuple[str, str], float] = {
    ("GIN", "NE"): 0.02,
}

MODELS = list(gnn.MODELS)
DSETS = list(DATASETS)


@functools.lru_cache(maxsize=64)
def full_adj_stripe_density(name: str, tile_m: int) -> tuple[np.ndarray, int]:
    """Row-stripe densities of the FULL-scale normalized adjacency, without
    materializing device arrays (regenerates the same edge stream)."""
    stats = DATASETS[name]
    seed = zlib.crc32(f"{name}:1.0".encode()) % (2**31)
    rng = np.random.default_rng(seed)
    src, dst = _gen_edges(rng, stats.vertices, stats.edges)
    rows = np.concatenate([src, np.arange(stats.vertices, dtype=np.int64)])
    n_stripes = -(-stats.vertices // tile_m)
    counts = np.bincount(rows // tile_m, minlength=n_stripes).astype(np.float64)
    sizes = np.full(n_stripes, tile_m * stats.vertices, dtype=np.float64)
    tail = stats.vertices - (n_stripes - 1) * tile_m
    sizes[-1] = tail * stats.vertices
    return counts / sizes, len(rows)


@dataclasses.dataclass
class Recording:
    model: str
    dataset: str
    scale: float
    kernels: list[dict]           # engine meta, in execution order
    wall_s: float                 # functional wall-clock at `scale`
    v_small: int
    f_small: int


@functools.lru_cache(maxsize=64)
def record(model: str, dataset: str) -> Recording:
    scale = SCALE_OVERRIDE.get((model, dataset),
                               FUNCTIONAL_SCALE[dataset])
    g = load_graph(dataset, scale=scale)
    in_dim = g.features.shape[1]
    params = gnn.init_params(model, in_dim, g.stats.hidden, g.stats.classes)
    eng = DynasparseEngine()
    t0 = time.perf_counter()
    logits, report = gnn.run_inference(model, eng, g.adj, g.features, params)
    np.asarray(logits)  # block
    wall = time.perf_counter() - t0
    return Recording(model, dataset, scale, list(report.meta), wall,
                     v_small=g.stats.vertices, f_small=g.stats.features)


def replay(model: str, dataset: str, hw: HardwareModel = VCK5000,
           mode: str = "dynamic", densify_features: bool = False,
           strategy: str = "balanced",
           ) -> tuple[ScheduleReport, float]:
    """Re-run analyzer+scheduler at FULL-scale geometry.

    Returns (merged report, end-to-end hardware time = Σ kernel makespans).
    ``densify_features=True`` reproduces Table V's "Sp. AM only" accounting:
    adjacency sparsity is exploited, feature/weight matrices treated dense.
    """
    rec = record(model, dataset)
    stats = DATASETS[dataset]
    dim_map = {rec.v_small: stats.vertices, rec.f_small: stats.features}

    total: ScheduleReport | None = None
    hw_time = 0.0
    for meta in rec.kernels:
        M = dim_map.get(meta["M"], meta["M"])
        K = dim_map.get(meta["K"], meta["K"])
        N = dim_map.get(meta["N"], meta["N"])
        tm, tn = choose_tile(M, N)
        tm, tn = min(tm, M), min(tn, N)
        nrt, nct = -(-M // tm), -(-N // tn)
        if meta["x_is_adj"]:
            row_d, _ = full_adj_stripe_density(dataset, tm)
            alpha_y = 1.0 if densify_features else meta["alpha_y"]
            col_d = np.full(nct, alpha_y)
        else:
            ax = 1.0 if densify_features else meta["alpha_x"]
            ay = 1.0 if densify_features else meta["alpha_y"]
            row_d = np.full(nrt, ax)
            col_d = np.full(nct, ay)
        part = make_tasks(meta["name"], M, K, N, row_d, col_d, tm, tn)
        if mode == "dynamic":
            stq, dtq = analyze_kernel(part, hw, strategy)
        else:
            stq, dtq = force_queue(part, hw,
                                   "STQ" if mode == "sparse_only" else "DTQ")
        rep = simulate(stq, dtq, hw)
        total = rep if total is None else total.merge(rep)
        hw_time += rep.makespan
    return total, hw_time


def fmt_ms(seconds: float) -> str:
    return f"{seconds * 1e3:.4g}"
