"""Paper Table VI + Figs 4/5: hardware execution time per model x dataset.

Our number = perf-model simulator (VCK5000 constants) on measured densities —
the paper's own methodology (§IV-A: cycle-accurate simulator + Ramulator DDR
model).  Paper reference rows are reproduced for the speedup columns; the
functional JAX wall-clock (CPU, at the functional scale) is the `us_per_call`
CSV value.
"""
from __future__ import annotations

from benchmarks.common import DSETS, MODELS, record, replay, fmt_ms

# Table VI "This paper" rows (ms)
PAPER_THIS = {
    ("GCN", "CO"): 9.40e-3, ("GCN", "CI"): 1.22e-2, ("GCN", "PU"): 8.65e-2,
    ("GCN", "FL"): 6.10e0, ("GCN", "NE"): 5.20e0, ("GCN", "RE"): 9.10e1,
    ("GraphSage", "CO"): 1.01e-1, ("GraphSage", "CI"): 2.51e-1,
    ("GraphSage", "PU"): 1.95e-1, ("GraphSage", "FL"): 1.91e0,
    ("GraphSage", "NE"): 5.07e2, ("GraphSage", "RE"): 2.81e2,
    ("GIN", "CO"): 1.02e-1, ("GIN", "CI"): 2.52e-1, ("GIN", "PU"): 2.05e-1,
    ("GIN", "FL"): 7.61e0, ("GIN", "NE"): 5.08e2, ("GIN", "RE"): 2.94e2,
    ("SGC", "CO"): 1.22e-1, ("SGC", "CI"): 3.14e-1, ("SGC", "PU"): 3.18e-1,
    ("SGC", "FL"): 3.29e0, ("SGC", "NE"): 7.82e1, ("SGC", "RE"): 4.71e2,
}
# Table VI baseline rows used for Fig 4/5-style speedup summaries (ms)
PAPER_PYG_CPU = {
    ("GCN", "CO"): 2.10, ("GCN", "CI"): 3.30, ("GCN", "PU"): 8.70,
    ("GCN", "FL"): 281.0, ("GCN", "NE"): 1540.0, ("GCN", "RE"): 32100.0,
}
PAPER_DYNASPARSE = {
    ("GCN", "CO"): 4.7e-3, ("GCN", "CI"): 7.7e-3, ("GCN", "PU"): 6.3e-2,
    ("GCN", "FL"): 8.8, ("GCN", "NE"): 2.9, ("GCN", "RE"): 100.0,
    ("GraphSage", "CO"): 1.11e-1, ("GraphSage", "CI"): 3.34e-1,
    ("GraphSage", "PU"): 4.21e-1, ("GraphSage", "FL"): 19.1,
    ("GraphSage", "NE"): 837.0, ("GraphSage", "RE"): 331.0,
    ("GIN", "CO"): 1.08e-1, ("GIN", "CI"): 3.29e-1, ("GIN", "PU"): 3.71e-1,
    ("GIN", "FL"): 12.1, ("GIN", "NE"): 837.0, ("GIN", "RE"): 273.0,
    ("SGC", "CO"): 2.67, ("SGC", "CI"): 8.7e-1, ("SGC", "PU"): 2.34,
    ("SGC", "FL"): 12.7, ("SGC", "NE"): 884.0, ("SGC", "RE"): 505.0,
}

MODEL_ALIAS = {"GraphSAGE": "GraphSage"}


def run(csv: list[str]) -> None:
    print("\n== Table VI: hardware execution time (ms), VCK5000 perf model ==")
    print(f"{'model':>10} {'ds':>3} {'ours ms':>10} {'paper ms':>10} "
          f"{'ratio':>7} {'vs dynasparse':>13} {'func wall ms':>12}")
    ratios = []
    for model in MODELS:
        pm = MODEL_ALIAS.get(model, model)
        for ds in DSETS:
            _, hw_time = replay(model, ds)
            ours_ms = hw_time * 1e3
            paper = PAPER_THIS.get((pm, ds))
            dyn = PAPER_DYNASPARSE.get((pm, ds))
            rec = record(model, ds)
            ratio = ours_ms / paper if paper else float("nan")
            ratios.append(ratio)
            spd = (dyn / ours_ms) if dyn else float("nan")
            print(f"{model:>10} {ds:>3} {ours_ms:10.4g} "
                  f"{paper if paper else float('nan'):10.4g} {ratio:7.2f} "
                  f"{spd:13.2f} {rec.wall_s * 1e3:12.4g}")
            csv.append(f"table_vi/{model}/{ds}/hw_time_ms,"
                       f"{rec.wall_s * 1e6:.1f},{ours_ms:.6g}")
    import numpy as np
    gm = float(np.exp(np.nanmean(np.log(ratios))))
    print(f"geomean(ours/paper) = {gm:.2f}x "
          "(|log-ratio| < ~3x ⇒ simulator tracks the paper's methodology)")
    csv.append(f"table_vi/geomean_ratio_vs_paper,,{gm:.4f}")

    # Fig 5-style summary: speedup over PyG-CPU for GCN
    print("\n-- Fig 5 (GCN speedup over PyG-CPU reference times) --")
    for ds in DSETS:
        _, hw_time = replay("GCN", ds)
        spd = PAPER_PYG_CPU[("GCN", ds)] / (hw_time * 1e3)
        print(f"  {ds}: {spd:9.1f}x")
        csv.append(f"fig5/GCN/{ds}/speedup_vs_pyg_cpu,,{spd:.2f}")
