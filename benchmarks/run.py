"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]``

Prints human-readable tables plus ``name,us_per_call,derived`` CSV lines at
the end (the CSV contract of the repo scaffold).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the big datasets (NE, RE)")
    ap.add_argument("--only", default=None,
                    help="comma list: v,vi,vii,viii,overheads,kernels")
    args = ap.parse_args()

    if args.fast:
        import benchmarks.common as common
        common.DSETS = [d for d in common.DSETS if d not in ("NE", "RE")]

    which = set((args.only or "v,vi,vii,viii,overheads,kernels").split(","))
    csv: list[str] = []
    t0 = time.time()

    from benchmarks import (kernel_bench, overheads, table_v_flops,
                            table_vi_latency, table_vii_heterogeneity,
                            table_viii_scaling)

    if "kernels" in which:
        kernel_bench.run(csv)
    if "v" in which:
        table_v_flops.run(csv)
    if "vi" in which:
        table_vi_latency.run(csv)
    if "vii" in which:
        table_vii_heterogeneity.run(csv)
    if "viii" in which:
        table_viii_scaling.run(csv)
    if "overheads" in which:
        overheads.run(csv)

    print(f"\n[benchmarks done in {time.time() - t0:.1f}s]")
    print("\nname,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
