"""Paper Table VII: PL-only vs PL+AIE (GCN) — the heterogeneity payoff.

PL-only = every task forced to the sparse engine (the paper's prior-design
baseline); PL+AIE = dynamic analyzer.  Paper reports 3.9-96.7x, avg 32.9x.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import DSETS, replay

PAPER_PL_ONLY_MS = {"CO": 2.45e-1, "CI": 7.26e-1, "PU": 6.55e-1,
                    "FL": 2.09e1, "NE": 5.02e2, "RE": 3.52e2}
PAPER_HYBRID_MS = {"CO": 9.40e-3, "CI": 1.22e-2, "PU": 8.65e-2,
                   "FL": 6.10e0, "NE": 5.20e0, "RE": 9.10e1}


def run(csv: list[str]) -> None:
    print("\n== Table VII: PL-only vs PL+AIE (GCN) ==")
    print(f"{'ds':>3} {'PL-only ms':>11} {'PL+AIE ms':>10} {'speedup':>8} "
          f"{'paper speedup':>13}")
    spds = []
    for ds in DSETS:
        # "PL Only" = BoostGCN-style pure-PL design: adjacency sparsity
        # exploited, feature matrices treated dense, no AIE (sparse engine
        # only) — matches the paper's PL-only row being ≈ BoostGCN's times.
        _, t_pl = replay("GCN", ds, mode="sparse_only",
                         densify_features=True)
        _, t_dyn = replay("GCN", ds, mode="dynamic")
        spd = t_pl / t_dyn
        spds.append(spd)
        paper_spd = PAPER_PL_ONLY_MS[ds] / PAPER_HYBRID_MS[ds]
        print(f"{ds:>3} {t_pl * 1e3:11.4g} {t_dyn * 1e3:10.4g} {spd:8.1f} "
              f"{paper_spd:13.1f}")
        csv.append(f"table_vii/{ds}/pl_vs_hybrid_speedup,,{spd:.2f}")
    print(f"average speedup: {np.mean(spds):.1f}x (paper avg: 32.9x)")
    csv.append(f"table_vii/avg_speedup,,{np.mean(spds):.2f}")
